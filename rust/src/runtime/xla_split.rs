//! XLA-accelerated split selection: the three-layer hot path.
//!
//! For a (large) node and one feature, the numeric rows are quantile-
//! binned (exact when distinct values ≤ B) and the histogram + prefix-sum
//! + information-gain scoring runs inside the AOT-compiled JAX/Pallas
//! module `split_select_m*` through PJRT. Categorical `=` candidates are
//! cheap and stay native. The returned split is a real `≤ edge` predicate,
//! so the tree built with this backend is a valid UDT tree.
//!
//! Scores come back as f32 (the kernel's dtype); the native engine keeps
//! f64. The `ablation_xla` bench quantifies the agreement.
//!
//! The PJRT path needs the external `xla` crate and therefore compiles
//! only under the `xla` cargo feature. Without it this module exposes a
//! stub [`XlaSelection`] whose loader returns `None` and whose selection
//! delegates to the exact native engine, so `Backend::Xla` stays
//! type-correct everywhere.

/// Tunables of the XLA backend.
#[derive(Debug, Clone)]
pub struct XlaSelectionConfig {
    /// Nodes smaller than this fall back to the native engine (the
    /// fixed per-call PJRT overhead dominates below it).
    pub min_rows: usize,
}

impl Default for XlaSelectionConfig {
    fn default() -> Self {
        Self { min_rows: 512 }
    }
}

#[cfg(feature = "xla")]
mod imp {
    use super::XlaSelectionConfig;
    use crate::data::interner::CatId;
    use crate::data::value::Value;
    use crate::error::{Result, UdtError};
    use crate::runtime::binning::quantile_bins;
    use crate::runtime::engine::{Engine, LoadedArtifact};
    use crate::selection::heuristic::{ClassCriterion, Criterion};
    use crate::selection::split::SplitOp;
    use crate::selection::superfast::{FeatureView, LabelsView, Scratch, ScoredSplit};
    use std::collections::BTreeMap;
    use std::sync::Mutex;

    /// The backend: a loaded engine + config.
    pub struct XlaSelection {
        engine: Engine,
        pub config: XlaSelectionConfig,
        /// PJRT executions are serialized; the CPU client is used from the
        /// coordinator's worker threads.
        lock: Mutex<()>,
    }

    // SAFETY: the PJRT CPU client and loaded executables are internally
    // thread-safe in XLA's C API; the `xla` crate just doesn't mark its
    // pointer wrappers. We additionally serialize `execute` calls with a
    // mutex, so no concurrent mutation of the wrapped objects occurs.
    unsafe impl Send for XlaSelection {}
    // SAFETY: same argument as the Send impl above; shared access to the
    // wrapped XLA objects is additionally serialized by `self.lock`.
    unsafe impl Sync for XlaSelection {}

    impl std::fmt::Debug for XlaSelection {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("XlaSelection")
                .field("config", &self.config)
                .field("artifacts", &self.engine.names())
                .finish()
        }
    }

    impl XlaSelection {
        pub fn new(engine: Engine, config: XlaSelectionConfig) -> Self {
            Self {
                engine,
                config,
                lock: Mutex::new(()),
            }
        }

        /// Load from the default artifacts directory.
        pub fn load_default(config: XlaSelectionConfig) -> Option<Self> {
            Engine::load_default().map(|e| Self::new(e, config))
        }

        pub fn engine(&self) -> &Engine {
            &self.engine
        }

        /// Best split on one feature. Falls back to the native engine for
        /// small nodes, non-info-gain criteria and regression views.
        pub fn best_split_on_feat(
            &self,
            view: &FeatureView,
            labels: &LabelsView,
            criterion: Criterion,
            scratch: &mut Scratch,
        ) -> Option<ScoredSplit> {
            let usable = matches!(
                (labels, criterion),
                (
                    LabelsView::Class { .. },
                    Criterion::Class(ClassCriterion::InfoGain)
                )
            ) && view.sorted_num.len() >= self.config.min_rows;
            if !usable {
                return crate::selection::superfast::best_split_on_feat_with(
                    view, labels, criterion, scratch,
                );
            }
            match self.xla_numeric_candidates(view, labels) {
                Ok(best_numeric) => {
                    // Categorical candidates stay native; combine.
                    let best_cat = self.native_categorical(view, labels, criterion);
                    match (best_numeric, best_cat) {
                        (Some(a), Some(b)) => Some(if a.score >= b.score { a } else { b }),
                        (a, b) => a.or(b),
                    }
                }
                Err(err) => {
                    // Robustness: degrade to the exact native path.
                    eprintln!("xla backend error ({err}); falling back to native");
                    crate::selection::superfast::best_split_on_feat_with(
                        view, labels, criterion, scratch,
                    )
                }
            }
        }

        /// Run the AOT module over the binned numeric rows.
        fn xla_numeric_candidates(
            &self,
            view: &FeatureView,
            labels: &LabelsView,
        ) -> Result<Option<ScoredSplit>> {
            let LabelsView::Class { ids, n_classes } = labels else {
                return Err(UdtError::runtime("xla path requires classification labels"));
            };
            let n = view.sorted_num.len();
            if n == 0 {
                return Ok(None);
            }
            let artifact: &LoadedArtifact = self.engine.variant_for(n, *n_classes)?;
            let (m_pad, b_bins, c_pad) = (artifact.spec.m, artifact.spec.b, artifact.spec.c);

            let binning =
                // ANALYZE-ALLOW(no-unwrap): dispatch only reaches here with numeric rows present
                quantile_bins(view.sorted_vals, b_bins).expect("non-empty numeric rows");

            // Assemble padded inputs.
            let mut bin_ids = vec![0i32; m_pad];
            let mut label_ids = vec![0i32; m_pad];
            let mut mask = vec![0f32; m_pad];
            for (i, &r) in view.sorted_num.iter().enumerate() {
                bin_ids[i] = binning.bin_of_sorted[i] as i32;
                label_ids[i] = ids[r as usize] as i32;
                mask[i] = 1.0;
            }
            // Per-class categorical+missing counts ("rest"), padded to C.
            let mut rest = vec![0f32; c_pad];
            for &r in view.rows {
                match view.col.get(r as usize) {
                    Value::Num(_) => {}
                    _ => rest[ids[r as usize] as usize] += 1.0,
                }
            }

            let inputs = [
                xla::Literal::vec1(&bin_ids),
                xla::Literal::vec1(&label_ids),
                xla::Literal::vec1(&mask),
                xla::Literal::vec1(&rest),
            ];
            let outputs = {
                // ANALYZE-ALLOW(no-unwrap): no user code runs under this lock, so it cannot be poisoned
                let _guard = self.lock.lock().unwrap();
                artifact.execute(&inputs)?
            };
            if outputs.len() != 2 {
                return Err(UdtError::runtime(format!(
                    "expected (le, gt) outputs, got {}",
                    outputs.len()
                )));
            }
            let le: Vec<f32> = outputs[0]
                .to_vec()
                .map_err(|e| UdtError::runtime(format!("le scores: {e:?}")))?;
            let gt: Vec<f32> = outputs[1]
                .to_vec()
                .map_err(|e| UdtError::runtime(format!("gt scores: {e:?}")))?;

            // Argmax over the used bins; the kernel marks empty-side
            // candidates with a large negative sentinel.
            let mut best: Option<ScoredSplit> = None;
            let used = binning.n_bins();
            for b in 0..used {
                for (scores, op) in [
                    (&le, SplitOp::Le(binning.edges[b])),
                    (&gt, SplitOp::Gt(binning.edges[b])),
                ] {
                    let s = scores[b] as f64;
                    if s > -1e29 {
                        let better = best.map_or(true, |bst| s > bst.score);
                        if better {
                            best = Some(ScoredSplit { score: s, op });
                        }
                    }
                }
            }
            Ok(best)
        }

        /// Native scoring of categorical `=` candidates (cheap: vocabularies
        /// are small compared to numeric cardinality).
        fn native_categorical(
            &self,
            view: &FeatureView,
            labels: &LabelsView,
            criterion: Criterion,
        ) -> Option<ScoredSplit> {
            let LabelsView::Class { ids, n_classes } = labels else {
                return None;
            };
            let Criterion::Class(crit) = criterion else {
                return None;
            };
            let c = *n_classes;
            let mut totals = vec![0.0f64; c];
            let mut cat: BTreeMap<u32, Vec<f64>> = BTreeMap::new();
            for &r in view.rows {
                let y = ids[r as usize] as usize;
                totals[y] += 1.0;
                if let Value::Cat(CatId(id)) = view.col.get(r as usize) {
                    cat.entry(id).or_insert_with(|| vec![0.0; c])[y] += 1.0;
                }
            }
            let all: f64 = totals.iter().sum();
            let mut best: Option<ScoredSplit> = None;
            let mut neg = vec![0.0f64; c];
            for (&id, counts) in &cat {
                let pos_total: f64 = counts.iter().sum();
                if pos_total == 0.0 || all - pos_total == 0.0 {
                    continue;
                }
                for y in 0..c {
                    neg[y] = totals[y] - counts[y];
                }
                let score = crit.score(counts, &neg);
                let better = best.map_or(true, |b| score > b.score);
                if better && score.is_finite() {
                    best = Some(ScoredSplit {
                        score,
                        op: SplitOp::Eq(CatId(id)),
                    });
                }
            }
            best
        }
    }
}

#[cfg(not(feature = "xla"))]
mod imp {
    use super::XlaSelectionConfig;
    use crate::selection::heuristic::Criterion;
    use crate::selection::superfast::{FeatureView, LabelsView, Scratch, ScoredSplit};

    /// Stub backend built without the `xla` feature: it can never be
    /// constructed through [`XlaSelection::load_default`] (which reports
    /// "no artifacts"), and if a value is ever obtained another way its
    /// selection is just the exact native engine.
    #[derive(Debug)]
    pub struct XlaSelection {
        pub config: XlaSelectionConfig,
    }

    impl XlaSelection {
        /// Artifacts cannot be executed without the `xla` feature; always
        /// `None` so callers degrade to the native path.
        pub fn load_default(_config: XlaSelectionConfig) -> Option<Self> {
            None
        }

        /// Exact native selection (the stub has no accelerator).
        pub fn best_split_on_feat(
            &self,
            view: &FeatureView,
            labels: &LabelsView,
            criterion: Criterion,
            scratch: &mut Scratch,
        ) -> Option<ScoredSplit> {
            crate::selection::superfast::best_split_on_feat_with(view, labels, criterion, scratch)
        }
    }
}

pub use imp::XlaSelection;
