//! PJRT execution engine.
//!
//! Wraps the `xla` crate: one CPU PJRT client, plus every artifact from
//! the manifest compiled **once** at startup (`HloModuleProto::from_text_file
//! → XlaComputation::from_proto → client.compile`). Python never runs at
//! request time; the HLO *text* interchange (not serialized protos) is
//! required because jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects — see /opt/xla-example/README.md.

use super::manifest::{ArtifactSpec, Manifest};
use crate::error::{Result, UdtError};
use std::collections::HashMap;
use std::path::Path;

/// A compiled artifact ready to execute.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| UdtError::runtime(format!("execute {}: {e:?}", self.spec.name)))?;
        let literal = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| UdtError::runtime(format!("execute {}: empty result", self.spec.name)))?
            .to_literal_sync()
            .map_err(|e| UdtError::runtime(format!("to_literal {}: {e:?}", self.spec.name)))?;
        // aot.py lowers with return_tuple=True: the single output is a tuple.
        literal
            .to_tuple()
            .map_err(|e| UdtError::runtime(format!("untuple {}: {e:?}", self.spec.name)))
    }
}

/// The engine: PJRT client + compiled executables by name.
pub struct Engine {
    pub manifest: Manifest,
    artifacts: HashMap<String, LoadedArtifact>,
    platform: String,
}

impl Engine {
    /// Load and compile every artifact under `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| UdtError::runtime(format!("pjrt cpu client: {e:?}")))?;
        let platform = client
            .platform_name();
        let mut artifacts = HashMap::new();
        for spec in &manifest.artifacts {
            let path = manifest.hlo_path(spec);
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
                UdtError::runtime(format!(
                    "artifact `{}`: parse {}: {e:?}",
                    spec.name,
                    path.display()
                ))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| UdtError::runtime(format!("compile `{}`: {e:?}", spec.name)))?;
            artifacts.insert(
                spec.name.clone(),
                LoadedArtifact {
                    spec: spec.clone(),
                    exe,
                },
            );
        }
        Ok(Engine {
            manifest,
            artifacts,
            platform,
        })
    }

    /// Try to load from the default artifacts directory; `None` when the
    /// artifacts have not been built (callers degrade to the native path).
    pub fn load_default() -> Option<Engine> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        match Engine::load(&dir) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("warning: failed to load artifacts from {}: {err:#}", dir.display());
                None
            }
        }
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    pub fn get(&self, name: &str) -> Result<&LoadedArtifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| UdtError::runtime(format!("unknown artifact `{name}`")))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Pick the smallest variant fitting `n` rows / `n_classes` classes.
    pub fn variant_for(&self, n: usize, n_classes: usize) -> Result<&LoadedArtifact> {
        let spec = self
            .manifest
            .variant_for(n, n_classes)
            .ok_or_else(|| {
                UdtError::runtime(format!("no artifact variant fits m={n}, c={n_classes}"))
            })?;
        self.get(&spec.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Engine tests requiring built artifacts live in
    /// `rust/tests/runtime_roundtrip.rs`; here we only cover the
    /// no-artifacts degradation path.
    #[test]
    fn load_default_missing_dir_is_none() {
        let old = std::env::var_os("UDT_ARTIFACTS");
        std::env::set_var("UDT_ARTIFACTS", "/nonexistent/udt-artifacts");
        assert!(Engine::load_default().is_none());
        match old {
            Some(v) => std::env::set_var("UDT_ARTIFACTS", v),
            None => std::env::remove_var("UDT_ARTIFACTS"),
        }
    }

    #[test]
    fn load_missing_manifest_errors() {
        assert!(Engine::load("/nonexistent/udt-artifacts").is_err());
    }
}
