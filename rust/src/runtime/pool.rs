//! Persistent worker-pool runtime.
//!
//! Every `parallel_map*` call used to pay `std::thread::scope` spawn +
//! join for a fresh set of OS threads — once per tree level in the
//! arena builder, once per round × level in boosting, once per batch in
//! compiled predict, once per parse in CSV ingest. On the shallow, wide
//! frontiers Superfast selection produces (thousands of sub-millisecond
//! node tasks) that spawn/join is a constant tax on exactly the hot
//! paths. This module replaces it with one process-wide pool: workers
//! are spawned lazily **once** (capped at [`cores`]` - 1` — the
//! submitting thread is always executor 0), park on a condvar when
//! idle, and are handed batches through a queue under one mutex.
//!
//! # Invariants
//!
//! - **Ordering**: results are written by item index into pre-sized
//!   slots; the output `Vec` is in input order regardless of which
//!   thread ran which item.
//! - **Thread-count invariance**: the mapping closure runs exactly once
//!   per item; nothing about the result depends on `n_threads`, block
//!   boundaries, or scheduling. The existing 1≡N property suites
//!   (`prop_builder`, `prop_binned`, `prop_inference`, `prop_ingest`)
//!   hold unchanged on the pooled runtime.
//! - **Block claiming**: executors claim contiguous *blocks* of indices
//!   per `fetch_add` (block size ≈ `n / (workers * 4)`, min 1) so
//!   tiny-task frontiers don't serialize on the cursor cache line.
//! - **Per-worker scratch**: `make_scratch` runs once per participating
//!   executor, never per item.
//! - **Bounded width**: at most `threads(n_threads)` executors touch a
//!   batch — the submitter plus up to `workers - 1` pool workers
//!   (enforced by the `extra_cap` pick condition).
//! - **Nested submission**: a batch task may itself submit a batch (the
//!   builder's small-frontier path parallelizes across features from
//!   inside level tasks). The submitter always participates and drives
//!   its own cursor to exhaustion, so progress never depends on a free
//!   pool worker — no deadlock, even with zero workers.
//! - **Panic contract**: a panicking task is caught by its executor;
//!   the first payload is re-raised on the *submitting* caller after
//!   the batch fully retires. The pool itself never wedges — no pool
//!   lock is held while user code runs, so no lock is ever poisoned,
//!   and the next batch runs normally.
//!
//! # Safety of the lifetime erasure
//!
//! The per-batch closure lives on the submitter's stack but is stored
//! in the global queue as `&'static (dyn Fn() + Sync)`. That transmute
//! is sound because of the retire protocol: a worker may only obtain
//! the job reference by incrementing `running` *under the pool lock*;
//! before `run_batch` returns, the submitter removes the queue entry
//! and waits under that same lock until `running == 0`. After that, no
//! worker holds or can ever re-acquire the reference, so it never
//! outlives the frame it points into.
//!
//! # Race witness (`check` / [`witness`])
//!
//! In debug builds and under `--cfg udt_check`, every result slot
//! carries a shadow-ownership tag driven by atomic CAS: an executor
//! must move a slot FREE → CLAIMED before taking its item and
//! CLAIMED → DONE after writing its result, and the submitter asserts
//! DONE before reading. Any double-claim, double-commit or
//! read-before-commit — i.e. any violation of the exclusivity argument
//! the `unsafe` blocks below rest on — aborts with a diagnostic
//! instead of silently corrupting. A seeded yield injector
//! ([`witness::set_yield_seed`]) perturbs the claim/park/retire
//! protocol points deterministically so stress tests widen the
//! interleavings they cover. Release builds compile all of it to
//! nothing (the tag set is a ZST there).

use std::any::Any;
use std::cell::UnsafeCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Number of logical CPUs, queried once per process and memoized.
///
/// `std::thread::available_parallelism` takes a syscall on most
/// platforms; the chunked predict path used to re-query it per batch.
pub fn cores() -> usize {
    static CORES: OnceLock<usize> = OnceLock::new();
    *CORES.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Resolve a requested thread count: `0` means "all cores"
/// ([`cores`]), anything else is taken literally. Always ≥ 1.
///
/// This is the single resolver for every `n_threads` knob in the crate
/// (builder, ingest, shard writer, predict, serve) — previously
/// `parallel_map_chunked` resolved 0 → all cores while
/// `parallel_map`/`parallel_map_scratch` clamped 0 → 1.
pub fn threads(requested: usize) -> usize {
    if requested == 0 {
        cores()
    } else {
        requested
    }
}

/// Snapshot of the pool's monotonic counters (see [`stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// OS threads ever spawned by the pool. At most `cores() - 1` for
    /// the lifetime of the process — the witness that a forest fit or
    /// boost run no longer spawns per level/round.
    pub threads_spawned_total: u64,
    /// Batches handed to the pool (sequential fast paths not counted).
    pub batches_submitted: u64,
    /// Items executed by any executor, pool worker or submitter.
    pub tasks_executed: u64,
    /// Times an idle worker woke from its park to re-scan the queue.
    pub park_wakeups: u64,
}

impl PoolStats {
    /// Counter increments since an earlier snapshot.
    pub fn delta_since(&self, earlier: &PoolStats) -> PoolStats {
        PoolStats {
            threads_spawned_total: self
                .threads_spawned_total
                .saturating_sub(earlier.threads_spawned_total),
            batches_submitted: self.batches_submitted.saturating_sub(earlier.batches_submitted),
            tasks_executed: self.tasks_executed.saturating_sub(earlier.tasks_executed),
            park_wakeups: self.park_wakeups.saturating_sub(earlier.park_wakeups),
        }
    }
}

/// Current values of the pool's monotonic counters.
pub fn stats() -> PoolStats {
    PoolStats {
        threads_spawned_total: POOL.threads_spawned_total.load(Ordering::Relaxed),
        batches_submitted: POOL.batches_submitted.load(Ordering::Relaxed),
        tasks_executed: POOL.tasks_executed.load(Ordering::Relaxed),
        park_wakeups: POOL.park_wakeups.load(Ordering::Relaxed),
    }
}

/// Stable identifiers for the pool's protocol points, fed to the
/// yield injector so one seed reproduces one interleaving schedule.
/// Ungated: point names are part of the protocol's vocabulary even
/// when the injector compiles to a no-op.
pub(crate) mod protocol_point {
    /// An executor is about to bump the batch cursor.
    pub const CLAIM: u64 = 1;
    /// Between claiming an index and taking its item.
    pub const TAKE: u64 = 2;
    /// Between computing a result and writing its slot.
    pub const COMMIT: u64 = 3;
    /// A pool worker picked an entry and is about to run the job.
    pub const PICKUP: u64 = 4;
    /// The submitter is about to dequeue and drain the batch.
    pub const RETIRE: u64 = 5;
    /// The submitter is about to push the entry onto the queue.
    pub const SUBMIT: u64 = 6;
}

/// Dynamic race witness: shadow-ownership tags + seeded yield
/// injection. Real in debug builds and under `--cfg udt_check`;
/// compiled to no-ops (ZST tags, empty hooks) otherwise, so the
/// release hot path pays nothing.
#[cfg(any(debug_assertions, udt_check))]
pub(crate) mod check {
    use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};

    const FREE: u8 = 0;
    const CLAIMED: u8 = 1;
    const DONE: u8 = 2;

    /// One shadow tag per batch slot, mirroring the ownership the
    /// cursor protocol is *supposed* to guarantee: FREE → CLAIMED
    /// (executor takes the index) → DONE (result written). Every
    /// transition is a CAS, so the first interleaving in which two
    /// executors own one index trips a [`violation`] instead of a
    /// silent double-write.
    ///
    /// All tag operations use `Relaxed` ordering **on purpose**: the
    /// witness must not add acquire/release edges the real protocol
    /// doesn't have, or it would synchronize racing threads and mask
    /// under TSan exactly the bugs it exists to catch.
    pub struct SlotTags(Vec<AtomicU8>);

    impl SlotTags {
        pub fn new(n: usize) -> SlotTags {
            SlotTags((0..n).map(|_| AtomicU8::new(FREE)).collect())
        }

        /// FREE → CLAIMED; aborts on a double-claim.
        pub fn claim(&self, i: usize) {
            if let Err(seen) =
                self.0[i].compare_exchange(FREE, CLAIMED, Ordering::Relaxed, Ordering::Relaxed)
            {
                violation(&format!(
                    "pool slot {i} double-claimed (tag {seen}, expected FREE): \
                     two executors own one index"
                ));
            }
        }

        /// CLAIMED → DONE; aborts on a commit without a claim.
        pub fn commit(&self, i: usize) {
            if let Err(seen) =
                self.0[i].compare_exchange(CLAIMED, DONE, Ordering::Relaxed, Ordering::Relaxed)
            {
                violation(&format!(
                    "pool slot {i} committed from tag {seen} (expected CLAIMED): \
                     result written without ownership"
                ));
            }
        }

        /// Submitter-side read barrier: the batch retired, so every
        /// slot must be DONE before its result is moved out.
        pub fn assert_done(&self, i: usize) {
            let seen = self.0[i].load(Ordering::Relaxed);
            if seen != DONE {
                violation(&format!(
                    "pool batch retired with slot {i} at tag {seen} (expected DONE): \
                     result read before commit"
                ));
            }
        }
    }

    /// In production a violation means memory is already suspect, so
    /// the only safe move is `abort`. Tests flip this to get a
    /// catchable panic instead (the abort path is untestable
    /// in-process).
    static PANIC_ON_VIOLATION: AtomicBool = AtomicBool::new(false);

    pub fn set_panic_on_violation(on: bool) {
        PANIC_ON_VIOLATION.store(on, Ordering::Relaxed);
    }

    #[cold]
    pub fn violation(msg: &str) -> ! {
        if PANIC_ON_VIOLATION.load(Ordering::Relaxed) {
            // ANALYZE-ALLOW(no-unwrap): failing loudly is this function's job; tests opt into panic over abort
            panic!("udt_check violation: {msg}");
        }
        eprintln!("udt_check violation: {msg}");
        std::process::abort();
    }

    /// Yield-injection seed; 0 (the default) disables injection.
    static YIELD_SEED: AtomicU64 = AtomicU64::new(0);

    pub fn set_yield_seed(seed: u64) {
        YIELD_SEED.store(seed, Ordering::Relaxed);
    }

    thread_local! {
        /// Per-thread protocol-point counter: makes the schedule a
        /// deterministic function of (seed, thread history, point)
        /// rather than of wall-clock timing.
        static TICK: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
    }

    fn splitmix64(mut z: u64) -> u64 {
        z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Maybe yield at a protocol point (~1 in 5 visits when a seed is
    /// set). Called at every claim/take/commit/pickup/retire/submit
    /// site so a stress run explores interleavings the scheduler would
    /// rarely produce on its own.
    pub fn interleave(point: u64) {
        let seed = YIELD_SEED.load(Ordering::Relaxed);
        if seed == 0 {
            return;
        }
        let tick = TICK.with(|c| {
            let v = c.get().wrapping_add(1);
            c.set(v);
            v
        });
        let z = splitmix64(seed ^ tick.rotate_left(17) ^ point.rotate_left(48));
        if z % 5 == 0 {
            std::thread::yield_now();
        }
    }
}

/// Release stubs: same surface as the gated `check` module, all no-ops
/// — `SlotTags` is a ZST, the hooks inline to nothing.
#[cfg(not(any(debug_assertions, udt_check)))]
pub(crate) mod check {
    pub struct SlotTags;

    impl SlotTags {
        #[inline(always)]
        pub fn new(_n: usize) -> SlotTags {
            SlotTags
        }
        #[inline(always)]
        pub fn claim(&self, _i: usize) {}
        #[inline(always)]
        pub fn commit(&self, _i: usize) {}
        #[inline(always)]
        pub fn assert_done(&self, _i: usize) {}
    }

    #[inline(always)]
    pub fn set_panic_on_violation(_on: bool) {}
    #[inline(always)]
    pub fn set_yield_seed(_seed: u64) {}
    #[inline(always)]
    pub fn interleave(_point: u64) {}
}

/// Test-harness surface of the race witness (`tests/race_witness.rs`
/// drives it): present in every build so test code compiles uniformly,
/// functional only in debug / `--cfg udt_check` builds.
#[doc(hidden)]
pub mod witness {
    pub use super::check::{set_panic_on_violation, set_yield_seed, SlotTags};
}

/// A cell written by exactly one executor (index ownership via the
/// batch cursor) and read only after the batch retires.
struct Slot<V>(UnsafeCell<Option<V>>);

// SAFETY: the cursor hands each index to exactly one executor, so no
// two threads ever touch the same slot concurrently; the submitter
// reads results only after observing `running == 0` under the pool
// mutex, which orders all writes before the reads.
unsafe impl<V: Send> Sync for Slot<V> {}

impl<V> Slot<V> {
    fn new(v: Option<V>) -> Self {
        Slot(UnsafeCell::new(v))
    }
}

/// Lifetime-erased per-batch job. Points into the submitting frame;
/// validity is guaranteed by the retire protocol (module docs).
type Job = &'static (dyn Fn() + Sync);

/// Shared state of one in-flight batch.
struct BatchCore {
    /// Next unclaimed item index; `fetch_add(block)` claims a block.
    cursor: AtomicUsize,
    n: usize,
    block: usize,
    /// Max *pool workers* that may join (the submitter is not counted),
    /// i.e. `workers - 1`. Enforces the caller's `n_threads` cap.
    extra_cap: usize,
    /// Pool workers currently inside the job. Modified only under the
    /// pool mutex so `done_cv` waits are sound.
    running: AtomicUsize,
    /// First panic payload from any executor of this batch.
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
    /// Shadow-ownership tags (debug / `--cfg udt_check` only; ZST in
    /// release). Witnesses the index-exclusivity argument the unsafe
    /// slot accesses rely on.
    tags: check::SlotTags,
}

struct Entry {
    core: Arc<BatchCore>,
    job: Job,
}

struct State {
    queue: Vec<Entry>,
}

struct Pool {
    state: Mutex<State>,
    /// Workers park here; notified on every submission.
    work_cv: Condvar,
    /// Submitters wait here for `running == 0`; notified when a worker
    /// leaves a job.
    done_cv: Condvar,
    /// Set once to the number of workers actually spawned.
    spawned: OnceLock<usize>,
    threads_spawned_total: AtomicU64,
    batches_submitted: AtomicU64,
    tasks_executed: AtomicU64,
    park_wakeups: AtomicU64,
}

static POOL: Pool = Pool {
    state: Mutex::new(State { queue: Vec::new() }),
    work_cv: Condvar::new(),
    done_cv: Condvar::new(),
    spawned: OnceLock::new(),
    threads_spawned_total: AtomicU64::new(0),
    batches_submitted: AtomicU64::new(0),
    tasks_executed: AtomicU64::new(0),
    park_wakeups: AtomicU64::new(0),
};

/// Indices claimed per `fetch_add`: enough blocks for ~4 claims per
/// executor so the tail balances, min 1.
fn block_size(n: usize, workers: usize) -> usize {
    (n / (workers.max(1) * 4)).max(1)
}

/// Spawn the worker threads exactly once; returns how many exist.
/// Spawn failure degrades gracefully: fewer (possibly zero) workers
/// simply means the submitter does more (or all) of the work.
fn ensure_workers() -> usize {
    *POOL.spawned.get_or_init(|| {
        let target = cores().saturating_sub(1);
        let mut spawned = 0usize;
        for i in 0..target {
            let ok = std::thread::Builder::new()
                .name(format!("udt-pool-{i}"))
                .spawn(worker_loop)
                .is_ok();
            if !ok {
                break;
            }
            spawned += 1;
        }
        POOL.threads_spawned_total
            .fetch_add(spawned as u64, Ordering::Relaxed);
        spawned
    })
}

fn worker_loop() {
    // ANALYZE-ALLOW(no-unwrap): no pool lock is ever held while user code runs (panic contract), so it cannot be poisoned
    let mut st = POOL.state.lock().unwrap();
    loop {
        let picked = st
            .queue
            .iter()
            .find(|e| {
                e.core.running.load(Ordering::Relaxed) < e.core.extra_cap
                    && e.core.cursor.load(Ordering::Relaxed) < e.core.n
            })
            .map(|e| (Arc::clone(&e.core), e.job));
        match picked {
            Some((core, job)) => {
                core.running.fetch_add(1, Ordering::Relaxed);
                drop(st);
                check::interleave(protocol_point::PICKUP);
                if let Err(payload) = catch_unwind(AssertUnwindSafe(job)) {
                    // ANALYZE-ALLOW(no-unwrap): the panic mutex only guards a payload swap — no user code, never poisoned
                    let mut slot = core.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
                // ANALYZE-ALLOW(no-unwrap): no pool lock is ever held while user code runs (panic contract), so it cannot be poisoned
                st = POOL.state.lock().unwrap();
                core.running.fetch_sub(1, Ordering::Relaxed);
                POOL.done_cv.notify_all();
            }
            None => {
                // ANALYZE-ALLOW(no-unwrap): condvar wait re-acquires the never-poisoned pool lock
                st = POOL.work_cv.wait(st).unwrap();
                POOL.park_wakeups.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Order-preserving parallel map with per-executor scratch, run on the
/// persistent pool. `n_threads == 0` means all cores; `1` is an inline
/// sequential fast path that never touches the pool.
pub fn map_scratch<T, R, S>(
    items: Vec<T>,
    n_threads: usize,
    make_scratch: impl Fn() -> S + Sync,
    f: impl Fn(T, &mut S) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = threads(n_threads).min(n);
    if workers == 1 || ensure_workers() == 0 {
        let mut scratch = make_scratch();
        return items.into_iter().map(|it| f(it, &mut scratch)).collect();
    }
    run_batch(items, workers, &make_scratch, &f)
}

fn run_batch<T, R, S>(
    items: Vec<T>,
    workers: usize,
    make_scratch: &(impl Fn() -> S + Sync),
    f: &(impl Fn(T, &mut S) -> R + Sync),
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    let n = items.len();
    let slots: Vec<Slot<T>> = items.into_iter().map(|t| Slot::new(Some(t))).collect();
    let results: Vec<Slot<R>> = (0..n).map(|_| Slot::new(None)).collect();
    let core = Arc::new(BatchCore {
        cursor: AtomicUsize::new(0),
        n,
        block: block_size(n, workers),
        extra_cap: workers - 1,
        running: AtomicUsize::new(0),
        panic: Mutex::new(None),
        tags: check::SlotTags::new(n),
    });

    let job = {
        let core = Arc::clone(&core);
        let slots = &slots;
        let results = &results;
        move || {
            let mut scratch = make_scratch();
            let mut done = 0u64;
            loop {
                check::interleave(protocol_point::CLAIM);
                let start = core.cursor.fetch_add(core.block, Ordering::Relaxed);
                if start >= core.n {
                    break;
                }
                let end = (start + core.block).min(core.n);
                for i in start..end {
                    core.tags.claim(i);
                    check::interleave(protocol_point::TAKE);
                    // SAFETY: the fetch_add above handed start..end to
                    // this executor exclusively (CAS-witnessed by the
                    // FREE → CLAIMED transition in debug builds).
                    // ANALYZE-ALLOW(no-unwrap): a freshly claimed index still holds its item by the same exclusivity
                    let item = unsafe { (*slots[i].0.get()).take() }.expect("item present");
                    let r = f(item, &mut scratch);
                    check::interleave(protocol_point::COMMIT);
                    // SAFETY: same exclusivity — this executor is the
                    // only writer of results[i], and the submitter
                    // reads it only after the batch retires.
                    unsafe { *results[i].0.get() = Some(r) };
                    core.tags.commit(i);
                }
                done += (end - start) as u64;
            }
            if done > 0 {
                POOL.tasks_executed.fetch_add(done, Ordering::Relaxed);
            }
        }
    };
    let job_ref: &(dyn Fn() + Sync) = &job;
    // SAFETY: retire protocol — the entry is dequeued and `running == 0`
    // is observed under the pool mutex before this frame returns, so no
    // worker can hold or re-acquire this reference afterwards (module
    // docs, "Safety of the lifetime erasure").
    let job_static: Job = unsafe {
        std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync)>(job_ref)
    };

    {
        check::interleave(protocol_point::SUBMIT);
        // ANALYZE-ALLOW(no-unwrap): no pool lock is ever held while user code runs (panic contract), so it cannot be poisoned
        let mut st = POOL.state.lock().unwrap();
        st.queue.push(Entry {
            core: Arc::clone(&core),
            job: job_static,
        });
    }
    POOL.batches_submitted.fetch_add(1, Ordering::Relaxed);
    POOL.work_cv.notify_all();

    // The submitter is always executor 0: it drives the cursor to
    // exhaustion itself, so the batch finishes even if every pool
    // worker is busy elsewhere (or parked in a nested submission).
    let mine = catch_unwind(AssertUnwindSafe(&job));

    // Retire: remove the entry so no new worker can pick it, then wait
    // for in-flight workers to leave. After this block the job
    // reference is unreachable.
    {
        check::interleave(protocol_point::RETIRE);
        // ANALYZE-ALLOW(no-unwrap): no pool lock is ever held while user code runs (panic contract), so it cannot be poisoned
        let mut st = POOL.state.lock().unwrap();
        st.queue.retain(|e| !Arc::ptr_eq(&e.core, &core));
        while core.running.load(Ordering::Relaxed) > 0 {
            // ANALYZE-ALLOW(no-unwrap): condvar wait re-acquires the never-poisoned pool lock
            st = POOL.done_cv.wait(st).unwrap();
        }
    }

    if let Err(payload) = mine {
        // ANALYZE-ALLOW(no-unwrap): the panic mutex only guards a payload swap — no user code, never poisoned
        let mut slot = core.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(payload);
        }
    }
    // ANALYZE-ALLOW(no-unwrap): the panic mutex only guards a payload swap — no user code, never poisoned
    if let Some(payload) = core.panic.lock().unwrap().take() {
        resume_unwind(payload);
    }

    results
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            // The witness's read barrier: every slot must have passed
            // CLAIMED → DONE before its result is moved out.
            core.tags.assert_done(i);
            // ANALYZE-ALLOW(no-unwrap): retirement (cursor exhausted, running == 0, no panic) implies every slot was written
            s.0.into_inner().expect("batch completed")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_threads_means_all_cores_everywhere() {
        // The satellite regression: 0 used to mean "all cores" for the
        // chunked path but "sequential" for map/map_scratch.
        assert_eq!(threads(0), cores());
        assert_eq!(threads(1), 1);
        assert_eq!(threads(7), 7);
        assert!(cores() >= 1);
        // And cores() is stable across calls (memoized).
        assert_eq!(cores(), cores());
    }

    #[test]
    fn map_preserves_order_with_zero_meaning_all_cores() {
        let items: Vec<usize> = (0..10_000).collect();
        let out = map_scratch(items, 0, || (), |x, _| x * 3);
        assert_eq!(out.len(), 10_000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 3);
        }
    }

    #[test]
    fn non_clone_items_move_through_the_pool() {
        let items: Vec<String> = (0..257).map(|i| format!("item-{i}")).collect();
        let out = map_scratch(items, 5, || (), |s, _| s + "!");
        assert_eq!(out.len(), 257);
        assert_eq!(out[0], "item-0!");
        assert_eq!(out[256], "item-256!");
    }

    #[test]
    fn scratch_is_per_executor_not_per_item() {
        use std::sync::atomic::AtomicUsize;
        static MADE: AtomicUsize = AtomicUsize::new(0);
        let items: Vec<u64> = (0..100).collect();
        let before = MADE.load(Ordering::Relaxed);
        let out = map_scratch(
            items,
            4,
            || {
                MADE.fetch_add(1, Ordering::Relaxed);
                0u64
            },
            |x, acc: &mut u64| {
                *acc += x;
                x
            },
        );
        let made = MADE.load(Ordering::Relaxed) - before;
        assert_eq!(out.iter().sum::<u64>(), (0..100).sum::<u64>());
        // At most one scratch per executor (≤ 4), never one per item.
        assert!((1..=4).contains(&made), "made {made} scratches");
    }

    #[test]
    fn panicking_batch_propagates_and_pool_stays_usable() {
        let items: Vec<usize> = (0..64).collect();
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            map_scratch(items, 4, || (), |x, _| {
                if x == 33 {
                    panic!("boom at {x}");
                }
                x
            })
        }));
        assert!(caught.is_err(), "panic must reach the submitter");
        // The pool is still fully usable for the next batch.
        let clean: Vec<usize> = (0..512).collect();
        let out = map_scratch(clean, 4, || (), |x, _| x + 1);
        assert_eq!(out.len(), 512);
        assert_eq!(out[511], 512);
    }

    #[test]
    fn nested_submission_completes() {
        // Outer level-batch tasks submit inner feature-batches, as the
        // builder's small-frontier path does. Must finish even when the
        // inner batches find every worker busy.
        let outer: Vec<usize> = (0..8).collect();
        let out = map_scratch(outer, 0, || (), |o, _| {
            let inner: Vec<usize> = (0..50).collect();
            map_scratch(inner, 0, || (), |i, _| i * o).iter().sum::<usize>()
        });
        for (o, v) in out.iter().enumerate() {
            assert_eq!(*v, o * (0..50).sum::<usize>());
        }
    }

    #[test]
    fn spawn_happens_at_most_once_per_process() {
        // Run real work twice; the global spawn counter must never
        // exceed cores() - 1 no matter how many batches (including
        // those from concurrently running tests) have executed.
        for _ in 0..2 {
            let items: Vec<usize> = (0..1000).collect();
            let out = map_scratch(items, 0, || (), |x, _| x ^ 1);
            assert_eq!(out.len(), 1000);
        }
        let s = stats();
        assert!(
            s.threads_spawned_total <= cores() as u64,
            "spawned {} threads on a {}-core machine",
            s.threads_spawned_total,
            cores()
        );
        if cores() > 1 {
            assert!(s.batches_submitted >= 2);
            assert!(s.tasks_executed >= 2000);
        }
    }

    #[test]
    fn block_size_scales_with_items_per_worker() {
        assert_eq!(block_size(0, 4), 1);
        assert_eq!(block_size(16, 4), 1);
        assert_eq!(block_size(1000, 4), 62);
        assert_eq!(block_size(100_000, 8), 3125);
        // Degenerate worker count never divides by zero.
        assert_eq!(block_size(10, 0), 2);
    }

    #[test]
    fn delta_since_subtracts_counters() {
        let a = PoolStats {
            threads_spawned_total: 3,
            batches_submitted: 10,
            tasks_executed: 100,
            park_wakeups: 7,
        };
        let b = PoolStats {
            threads_spawned_total: 3,
            batches_submitted: 14,
            tasks_executed: 260,
            park_wakeups: 9,
        };
        let d = b.delta_since(&a);
        assert_eq!(d.threads_spawned_total, 0);
        assert_eq!(d.batches_submitted, 4);
        assert_eq!(d.tasks_executed, 160);
        assert_eq!(d.park_wakeups, 2);
    }
}
