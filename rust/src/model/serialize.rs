//! Versioned JSON serialization of [`SavedModel`]: one self-contained
//! document bundling the model family, every tree, the [`Schema`] and the
//! categorical string interner — so `udt serve`/`udt predict` round-trip
//! *any* model without the training data.
//!
//! Document shape (version 1):
//!
//! ```text
//! {
//!   "format": "udt-model", "version": 1, "kind": "tuned_tree",
//!   "schema":   {"features": [{"name": ..., "kind": ...}], "classes": [...]},
//!   "interner": ["str0", "str1", ...],          // id i == names[i]
//!   "tree":     {...},                          // single_tree / tuned_tree
//!   "tuned":    {"max_depth": 7, "min_split": 40},  // tuned_tree only
//!   "trees":    [{...}, ...], "n_classes": 3    // forest only
//! }
//! ```
//!
//! Legacy bare-tree documents (the pre-model `train --out` output: a JSON
//! object with a top-level `"nodes"` array and no `"format"` key) still
//! load, as a [`Model::SingleTree`] with a placeholder schema.

use super::{Model, SavedModel, Schema};
use crate::data::dataset::TaskKind;
use crate::data::interner::Interner;
use crate::error::{Result, UdtError};
use crate::tree::forest::Forest;
use crate::tree::serialize as tree_serialize;
use crate::util::json::Json;
use std::path::Path;

/// Format tag of model documents.
pub const FORMAT: &str = "udt-model";
/// Current document version.
pub const VERSION: usize = 1;

impl SavedModel {
    /// Serialize to a versioned JSON document.
    pub fn to_json(&self) -> Json {
        let interner_names: Vec<Json> = self
            .interner
            .names()
            .iter()
            .map(|s| Json::Str(s.clone()))
            .collect();
        let mut fields: Vec<(&str, Json)> = vec![
            ("format", Json::Str(FORMAT.to_string())),
            ("version", Json::Num(VERSION as f64)),
            ("kind", Json::Str(self.model.kind().to_string())),
            ("schema", self.schema.to_json()),
            ("interner", Json::Arr(interner_names)),
        ];
        match &self.model {
            Model::SingleTree(tree) => {
                fields.push(("tree", tree_serialize::to_json(tree, &self.interner)));
            }
            Model::TunedTree {
                tree,
                max_depth,
                min_split,
            } => {
                fields.push(("tree", tree_serialize::to_json(tree, &self.interner)));
                fields.push((
                    "tuned",
                    Json::obj(vec![
                        ("max_depth", Json::Num(*max_depth as f64)),
                        ("min_split", Json::Num(*min_split as f64)),
                    ]),
                ));
            }
            Model::Forest(forest) => {
                let trees: Vec<Json> = forest
                    .trees
                    .iter()
                    .map(|t| tree_serialize::to_json(t, &self.interner))
                    .collect();
                fields.push(("trees", Json::Arr(trees)));
                fields.push(("n_classes", Json::Num(forest.n_classes as f64)));
            }
        }
        Json::obj(fields)
    }

    /// Parse a model document (current format or a legacy bare tree).
    pub fn from_json(json: &Json) -> Result<SavedModel> {
        match json.get("format").and_then(Json::as_str) {
            Some(FORMAT) => {}
            Some(other) => {
                return Err(UdtError::model(format!("unknown model format `{other}`")));
            }
            None => return load_legacy_tree(json),
        }
        let version = json
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| UdtError::model("missing `version`"))?;
        if version != VERSION {
            return Err(UdtError::model(format!(
                "unsupported model version {version} (this build reads version {VERSION})"
            )));
        }
        let schema = Schema::from_json(
            json.get("schema")
                .ok_or_else(|| UdtError::model("missing `schema`"))?,
        )?;
        let mut interner = Interner::new();
        for (i, name) in json
            .get("interner")
            .and_then(Json::as_arr)
            .ok_or_else(|| UdtError::model("missing `interner`"))?
            .iter()
            .enumerate()
        {
            let s = name
                .as_str()
                .ok_or_else(|| UdtError::model(format!("interner entry {i} must be a string")))?;
            interner.intern(s);
        }

        let kind = json
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| UdtError::model("missing `kind`"))?;
        let model = match kind {
            "single_tree" => Model::SingleTree(require_tree(json, &mut interner)?),
            "tuned_tree" => {
                let tree = require_tree(json, &mut interner)?;
                let tuned = json
                    .get("tuned")
                    .ok_or_else(|| UdtError::model("tuned_tree: missing `tuned`"))?;
                let max_depth = tuned
                    .get("max_depth")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| UdtError::model("tuned_tree: missing `tuned.max_depth`"))?;
                let min_split = tuned
                    .get("min_split")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| UdtError::model("tuned_tree: missing `tuned.min_split`"))?;
                if max_depth < 1 {
                    return Err(UdtError::model("tuned_tree: max_depth must be >= 1"));
                }
                Model::TunedTree {
                    tree,
                    max_depth,
                    min_split,
                }
            }
            "forest" => {
                let tree_docs = json
                    .get("trees")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| UdtError::model("forest: missing `trees`"))?;
                if tree_docs.is_empty() {
                    return Err(UdtError::model("forest: must contain at least one tree"));
                }
                let n_classes = json
                    .get("n_classes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| UdtError::model("forest: missing `n_classes`"))?;
                let mut trees = Vec::with_capacity(tree_docs.len());
                for (i, doc) in tree_docs.iter().enumerate() {
                    let tree = tree_serialize::from_json(doc, &mut interner)
                        .map_err(|e| UdtError::model(format!("forest tree {i}: {e}")))?;
                    trees.push(tree);
                }
                let task = trees[0].task;
                let n_features = trees[0].n_features;
                if trees
                    .iter()
                    .any(|t| t.task != task || t.n_features != n_features)
                {
                    return Err(UdtError::model(
                        "forest: member trees disagree on task or feature count",
                    ));
                }
                if task == TaskKind::Classification {
                    // Out-of-range node labels would silently lose their
                    // votes in the ensemble aggregation.
                    let max_class = trees
                        .iter()
                        .flat_map(|t| t.nodes.iter())
                        .filter_map(|n| n.label.as_class())
                        .max()
                        .unwrap_or(0);
                    if max_class as usize >= n_classes {
                        return Err(UdtError::model(format!(
                            "forest: node label class {max_class} out of range \
                             (n_classes {n_classes})"
                        )));
                    }
                }
                Model::Forest(Forest {
                    trees,
                    task,
                    n_classes,
                })
            }
            other => return Err(UdtError::model(format!("unknown model kind `{other}`"))),
        };

        if schema.n_features() != model.n_features() {
            return Err(UdtError::model(format!(
                "schema lists {} features but the model expects {}",
                schema.n_features(),
                model.n_features()
            )));
        }
        if model.task() == TaskKind::Regression && !schema.class_names.is_empty() {
            return Err(UdtError::model(
                "regression model cannot carry class names",
            ));
        }

        Ok(SavedModel {
            model,
            schema,
            interner,
        })
    }

    /// Write the pretty-printed document to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_pretty())?;
        Ok(())
    }

    /// Load a model document from `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<SavedModel> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| UdtError::model(format!("reading {}: {e}", path.display())))?;
        let json =
            Json::parse(&text).map_err(|e| UdtError::model(format!("{}: {e}", path.display())))?;
        Self::from_json(&json)
    }
}

fn require_tree(json: &Json, interner: &mut Interner) -> Result<crate::tree::Tree> {
    let doc = json
        .get("tree")
        .ok_or_else(|| UdtError::model("missing `tree`"))?;
    tree_serialize::from_json(doc, interner)
}

fn load_legacy_tree(json: &Json) -> Result<SavedModel> {
    if json.get("nodes").is_none() {
        return Err(UdtError::model(
            "not a udt model document (no `format` tag and no `nodes` array)",
        ));
    }
    let mut interner = Interner::new();
    let tree = tree_serialize::from_json(json, &mut interner)?;
    let schema = Schema::unnamed(tree.n_features);
    Ok(SavedModel {
        model: Model::SingleTree(tree),
        schema,
        interner,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_any, generate_classification, SynthSpec};
    use crate::model::Udt;
    use crate::tree::forest::ForestConfig;
    use crate::tree::TrainConfig;
    use crate::tree::Tree;

    fn cat_ds() -> crate::data::dataset::Dataset {
        let mut spec = SynthSpec::classification("ser", 500, 5, 3);
        spec.cat_frac = 0.4;
        generate_classification(&spec, 101)
    }

    fn round_trip(saved: &SavedModel) -> SavedModel {
        let text = saved.to_json().to_pretty();
        SavedModel::from_json(&Json::parse(&text).unwrap()).unwrap()
    }

    #[test]
    fn single_tree_round_trip_preserves_predictions_and_schema() {
        let ds = cat_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let saved = SavedModel::new(Model::SingleTree(tree), &ds);
        let back = round_trip(&saved);
        assert_eq!(back.model.kind(), "single_tree");
        assert_eq!(back.schema.feature_names, saved.schema.feature_names);
        assert_eq!(back.interner.len(), saved.interner.len());
        for r in (0..ds.n_rows()).step_by(17) {
            let row = ds.row(r);
            assert_eq!(
                back.model.predict_row(&row).unwrap(),
                saved.model.predict_row(&row).unwrap()
            );
        }
    }

    #[test]
    fn tuned_tree_round_trip_keeps_caps() {
        let ds = cat_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let saved = SavedModel::new(
            Model::TunedTree {
                tree,
                max_depth: 3,
                min_split: 25,
            },
            &ds,
        );
        let back = round_trip(&saved);
        match &back.model {
            Model::TunedTree {
                max_depth,
                min_split,
                ..
            } => {
                assert_eq!(*max_depth, 3);
                assert_eq!(*min_split, 25);
            }
            other => panic!("expected tuned tree, got {}", other.kind()),
        }
        for r in (0..ds.n_rows()).step_by(13) {
            let row = ds.row(r);
            assert_eq!(
                back.model.predict_row(&row).unwrap(),
                saved.model.predict_row(&row).unwrap()
            );
        }
    }

    #[test]
    fn forest_round_trip_preserves_votes() {
        let ds = cat_ds();
        let forest = Forest::fit(
            &ds,
            &ForestConfig {
                n_trees: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let saved = SavedModel::new(Model::Forest(forest), &ds);
        let back = round_trip(&saved);
        assert_eq!(back.model.kind(), "forest");
        for r in (0..ds.n_rows()).step_by(19) {
            let row = ds.row(r);
            assert_eq!(
                back.model.predict_row(&row).unwrap(),
                saved.model.predict_row(&row).unwrap()
            );
        }
    }

    #[test]
    fn regression_model_round_trips() {
        let ds = generate_any(&SynthSpec::regression("serreg", 300, 4), 7);
        let tree = Udt::builder().fit(&ds).unwrap();
        let saved = SavedModel::new(Model::SingleTree(tree), &ds);
        let back = round_trip(&saved);
        let row = ds.row(5);
        assert_eq!(
            back.model.predict_row(&row).unwrap(),
            saved.model.predict_row(&row).unwrap()
        );
    }

    #[test]
    fn legacy_bare_tree_documents_still_load() {
        let ds = cat_ds();
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let legacy = tree_serialize::to_json(&tree, &ds.interner).to_pretty();
        let saved = SavedModel::from_json(&Json::parse(&legacy).unwrap()).unwrap();
        assert_eq!(saved.model.kind(), "single_tree");
        assert_eq!(saved.schema.n_features(), ds.n_features());
    }

    #[test]
    fn malformed_documents_are_typed_model_errors() {
        for doc in [
            "{}",
            r#"{"format":"udt-model"}"#,
            r#"{"format":"udt-model","version":99,"kind":"single_tree"}"#,
            r#"{"format":"not-a-model","version":1}"#,
            r#"{"format":"udt-model","version":1,"kind":"alien",
                "schema":{"features":[],"classes":[]},"interner":[]}"#,
            r#"{"format":"udt-model","version":1,"kind":"forest",
                "schema":{"features":[],"classes":[]},"interner":[],
                "trees":[],"n_classes":2}"#,
        ] {
            let parsed = Json::parse(doc).unwrap();
            assert!(
                matches!(SavedModel::from_json(&parsed), Err(UdtError::Model(_))),
                "{doc}"
            );
        }
    }
}
