//! Versioned JSON serialization of [`SavedModel`]: one self-contained
//! document bundling the model family, every tree, the [`Schema`] and the
//! categorical string interner — so `udt serve`/`udt predict` round-trip
//! *any* model without the training data.
//!
//! Document shape (version 1):
//!
//! ```text
//! {
//!   "format": "udt-model", "version": 1, "kind": "tuned_tree",
//!   "schema":   {"features": [{"name": ..., "kind": ...}], "classes": [...]},
//!   "interner": ["str0", "str1", ...],          // id i == names[i]
//!   "tree":     {...},                          // single_tree / tuned_tree
//!   "tuned":    {"max_depth": 7, "min_split": 40},  // tuned_tree only
//!   "trees":    [{...}, ...],                   // forest / boosted members
//!   "n_classes": 3,                             // forest only
//!   "boost":    {"task": "classification", "n_classes": 3,
//!                "learning_rate": 0.1, "base": [...]}  // boosted only
//! }
//! ```
//!
//! Legacy bare-tree documents (the pre-model `train --out` output: a JSON
//! object with a top-level `"nodes"` array and no `"format"` key) still
//! load, as a [`Model::SingleTree`] with a placeholder schema.

use super::{Model, SavedModel, Schema};
use crate::data::dataset::TaskKind;
use crate::data::interner::Interner;
use crate::error::{Result, UdtError};
use crate::tree::forest::Forest;
use crate::tree::serialize as tree_serialize;
use crate::util::json::Json;
use std::path::Path;

/// Format tag of model documents.
pub const FORMAT: &str = "udt-model";
/// Current document version.
pub const VERSION: usize = 1;

impl SavedModel {
    /// Serialize to a versioned JSON document.
    pub fn to_json(&self) -> Json {
        let interner_names: Vec<Json> = self
            .interner
            .names()
            .iter()
            .map(|s| Json::Str(s.clone()))
            .collect();
        let mut fields: Vec<(&str, Json)> = vec![
            ("format", Json::Str(FORMAT.to_string())),
            ("version", Json::Num(VERSION as f64)),
            ("kind", Json::Str(self.model.kind().to_string())),
            ("schema", self.schema.to_json()),
            ("interner", Json::Arr(interner_names)),
        ];
        match &self.model {
            Model::SingleTree(tree) => {
                fields.push(("tree", tree_serialize::to_json(tree, &self.interner)));
            }
            Model::TunedTree {
                tree,
                max_depth,
                min_split,
            } => {
                fields.push(("tree", tree_serialize::to_json(tree, &self.interner)));
                fields.push((
                    "tuned",
                    Json::obj(vec![
                        ("max_depth", Json::Num(*max_depth as f64)),
                        ("min_split", Json::Num(*min_split as f64)),
                    ]),
                ));
            }
            Model::Forest(forest) => {
                let trees: Vec<Json> = forest
                    .trees
                    .iter()
                    .map(|t| tree_serialize::to_json(t, &self.interner))
                    .collect();
                fields.push(("trees", Json::Arr(trees)));
                fields.push(("n_classes", Json::Num(forest.n_classes as f64)));
            }
            Model::Boosted(boosted) => {
                let trees: Vec<Json> = boosted
                    .trees
                    .iter()
                    .map(|t| tree_serialize::to_json(t, &self.interner))
                    .collect();
                fields.push(("trees", Json::Arr(trees)));
                fields.push((
                    "boost",
                    Json::obj(vec![
                        (
                            "task",
                            Json::Str(
                                match boosted.task {
                                    TaskKind::Classification => "classification",
                                    TaskKind::Regression => "regression",
                                }
                                .to_string(),
                            ),
                        ),
                        ("n_classes", Json::Num(boosted.n_classes as f64)),
                        ("learning_rate", Json::Num(boosted.learning_rate)),
                        (
                            "base",
                            Json::Arr(boosted.base.iter().map(|&b| Json::Num(b)).collect()),
                        ),
                    ]),
                ));
            }
        }
        Json::obj(fields)
    }

    /// Parse a model document (current format or a legacy bare tree).
    pub fn from_json(json: &Json) -> Result<SavedModel> {
        match json.get("format").and_then(Json::as_str) {
            Some(FORMAT) => {}
            Some(other) => {
                return Err(UdtError::model(format!("unknown model format `{other}`")));
            }
            None => return load_legacy_tree(json),
        }
        let version = json
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| UdtError::model("missing `version`"))?;
        if version != VERSION {
            return Err(UdtError::model(format!(
                "unsupported model version {version} (this build reads version {VERSION})"
            )));
        }
        let schema = Schema::from_json(
            json.get("schema")
                .ok_or_else(|| UdtError::model("missing `schema`"))?,
        )?;
        let mut interner = Interner::new();
        for (i, name) in json
            .get("interner")
            .and_then(Json::as_arr)
            .ok_or_else(|| UdtError::model("missing `interner`"))?
            .iter()
            .enumerate()
        {
            let s = name
                .as_str()
                .ok_or_else(|| UdtError::model(format!("interner entry {i} must be a string")))?;
            interner.intern(s);
        }

        let kind = json
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| UdtError::model("missing `kind`"))?;
        let model = match kind {
            "single_tree" => Model::SingleTree(require_tree(json, &mut interner)?),
            "tuned_tree" => {
                let tree = require_tree(json, &mut interner)?;
                let tuned = json
                    .get("tuned")
                    .ok_or_else(|| UdtError::model("tuned_tree: missing `tuned`"))?;
                let max_depth = tuned
                    .get("max_depth")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| UdtError::model("tuned_tree: missing `tuned.max_depth`"))?;
                let min_split = tuned
                    .get("min_split")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| UdtError::model("tuned_tree: missing `tuned.min_split`"))?;
                if max_depth < 1 {
                    return Err(UdtError::model("tuned_tree: max_depth must be >= 1"));
                }
                Model::TunedTree {
                    tree,
                    max_depth,
                    min_split,
                }
            }
            "forest" => {
                let tree_docs = json
                    .get("trees")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| UdtError::model("forest: missing `trees`"))?;
                if tree_docs.is_empty() {
                    return Err(UdtError::model("forest: must contain at least one tree"));
                }
                let n_classes = json
                    .get("n_classes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| UdtError::model("forest: missing `n_classes`"))?;
                let mut trees = Vec::with_capacity(tree_docs.len());
                for (i, doc) in tree_docs.iter().enumerate() {
                    let tree = tree_serialize::from_json(doc, &mut interner)
                        .map_err(|e| UdtError::model(format!("forest tree {i}: {e}")))?;
                    trees.push(tree);
                }
                let task = trees[0].task;
                let n_features = trees[0].n_features;
                if trees
                    .iter()
                    .any(|t| t.task != task || t.n_features != n_features)
                {
                    return Err(UdtError::model(
                        "forest: member trees disagree on task or feature count",
                    ));
                }
                if task == TaskKind::Classification {
                    // Out-of-range node labels would silently lose their
                    // votes in the ensemble aggregation.
                    let max_class = trees
                        .iter()
                        .flat_map(|t| t.nodes.iter())
                        .filter_map(|n| n.label.as_class())
                        .max()
                        .unwrap_or(0);
                    if max_class as usize >= n_classes {
                        return Err(UdtError::model(format!(
                            "forest: node label class {max_class} out of range \
                             (n_classes {n_classes})"
                        )));
                    }
                }
                Model::Forest(Forest {
                    trees,
                    task,
                    n_classes,
                })
            }
            "boosted" => {
                let tree_docs = json
                    .get("trees")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| UdtError::model("boosted: missing `trees`"))?;
                if tree_docs.is_empty() {
                    return Err(UdtError::model("boosted: must contain at least one tree"));
                }
                let boost = json
                    .get("boost")
                    .ok_or_else(|| UdtError::model("boosted: missing `boost`"))?;
                let task = match boost.get("task").and_then(Json::as_str) {
                    Some("classification") => TaskKind::Classification,
                    Some("regression") => TaskKind::Regression,
                    other => {
                        return Err(UdtError::model(format!("boosted: bad task {other:?}")))
                    }
                };
                let n_classes = boost
                    .get("n_classes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| UdtError::model("boosted: missing `boost.n_classes`"))?;
                let learning_rate = boost
                    .get("learning_rate")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| UdtError::model("boosted: missing `boost.learning_rate`"))?;
                if !learning_rate.is_finite() || learning_rate <= 0.0 {
                    return Err(UdtError::model(format!(
                        "boosted: learning_rate must be finite and > 0, got {learning_rate}"
                    )));
                }
                let base: Vec<f64> = boost
                    .get("base")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| UdtError::model("boosted: missing `boost.base`"))?
                    .iter()
                    .enumerate()
                    .map(|(i, b)| {
                        b.as_f64().filter(|x| x.is_finite()).ok_or_else(|| {
                            UdtError::model(format!(
                                "boosted: base entry {i} must be a finite number"
                            ))
                        })
                    })
                    .collect::<Result<_>>()?;
                match task {
                    TaskKind::Classification if n_classes < 2 => {
                        return Err(UdtError::model(format!(
                            "boosted: classification needs n_classes >= 2, got {n_classes}"
                        )));
                    }
                    TaskKind::Regression if n_classes != 0 => {
                        return Err(UdtError::model(
                            "boosted: regression carries no classes (n_classes must be 0)",
                        ));
                    }
                    _ => {}
                }
                let group = crate::tree::boost::group_of(task, n_classes);
                if base.len() != group {
                    return Err(UdtError::model(format!(
                        "boosted: base has {} entries but the model has {group} score \
                         channel(s)",
                        base.len()
                    )));
                }
                if tree_docs.len() % group != 0 {
                    return Err(UdtError::model(format!(
                        "boosted: {} trees do not tile {group} score channel(s)",
                        tree_docs.len()
                    )));
                }
                let mut trees = Vec::with_capacity(tree_docs.len());
                for (i, doc) in tree_docs.iter().enumerate() {
                    let tree = tree_serialize::from_json(doc, &mut interner)
                        .map_err(|e| UdtError::model(format!("boosted tree {i}: {e}")))?;
                    trees.push(tree);
                }
                let n_features = trees[0].n_features;
                if trees
                    .iter()
                    .any(|t| t.task != TaskKind::Regression || t.n_features != n_features)
                {
                    return Err(UdtError::model(
                        "boosted: member trees must all be regression trees over the same \
                         feature space",
                    ));
                }
                Model::Boosted(crate::tree::boost::Boosted {
                    trees,
                    task,
                    n_features,
                    n_classes,
                    learning_rate,
                    base,
                })
            }
            other => return Err(UdtError::model(format!("unknown model kind `{other}`"))),
        };

        if schema.n_features() != model.n_features() {
            return Err(UdtError::model(format!(
                "schema lists {} features but the model expects {}",
                schema.n_features(),
                model.n_features()
            )));
        }
        if model.task() == TaskKind::Regression && !schema.class_names.is_empty() {
            return Err(UdtError::model(
                "regression model cannot carry class names",
            ));
        }

        Ok(SavedModel {
            model,
            schema,
            interner,
        })
    }

    /// Write the pretty-printed document to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_pretty())?;
        Ok(())
    }

    /// Load a model document from `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<SavedModel> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| UdtError::model(format!("reading {}: {e}", path.display())))?;
        let json =
            Json::parse(&text).map_err(|e| UdtError::model(format!("{}: {e}", path.display())))?;
        Self::from_json(&json)
    }
}

fn require_tree(json: &Json, interner: &mut Interner) -> Result<crate::tree::Tree> {
    let doc = json
        .get("tree")
        .ok_or_else(|| UdtError::model("missing `tree`"))?;
    tree_serialize::from_json(doc, interner)
}

fn load_legacy_tree(json: &Json) -> Result<SavedModel> {
    if json.get("nodes").is_none() {
        return Err(UdtError::model(
            "not a udt model document (no `format` tag and no `nodes` array)",
        ));
    }
    let mut interner = Interner::new();
    let tree = tree_serialize::from_json(json, &mut interner)?;
    let schema = Schema::unnamed(tree.n_features);
    Ok(SavedModel {
        model: Model::SingleTree(tree),
        schema,
        interner,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_any, generate_classification, SynthSpec};
    use crate::model::Udt;
    use crate::tree::forest::ForestConfig;
    use crate::tree::TrainConfig;
    use crate::tree::Tree;

    fn cat_ds() -> crate::data::dataset::Dataset {
        let mut spec = SynthSpec::classification("ser", 500, 5, 3);
        spec.cat_frac = 0.4;
        generate_classification(&spec, 101)
    }

    fn round_trip(saved: &SavedModel) -> SavedModel {
        let text = saved.to_json().to_pretty();
        SavedModel::from_json(&Json::parse(&text).unwrap()).unwrap()
    }

    #[test]
    fn single_tree_round_trip_preserves_predictions_and_schema() {
        let ds = cat_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let saved = SavedModel::new(Model::SingleTree(tree), &ds);
        let back = round_trip(&saved);
        assert_eq!(back.model.kind(), "single_tree");
        assert_eq!(back.schema.feature_names, saved.schema.feature_names);
        assert_eq!(back.interner.len(), saved.interner.len());
        for r in (0..ds.n_rows()).step_by(17) {
            let row = ds.row(r);
            assert_eq!(
                back.model.predict_row(&row).unwrap(),
                saved.model.predict_row(&row).unwrap()
            );
        }
    }

    #[test]
    fn tuned_tree_round_trip_keeps_caps() {
        let ds = cat_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let saved = SavedModel::new(
            Model::TunedTree {
                tree,
                max_depth: 3,
                min_split: 25,
            },
            &ds,
        );
        let back = round_trip(&saved);
        match &back.model {
            Model::TunedTree {
                max_depth,
                min_split,
                ..
            } => {
                assert_eq!(*max_depth, 3);
                assert_eq!(*min_split, 25);
            }
            other => panic!("expected tuned tree, got {}", other.kind()),
        }
        for r in (0..ds.n_rows()).step_by(13) {
            let row = ds.row(r);
            assert_eq!(
                back.model.predict_row(&row).unwrap(),
                saved.model.predict_row(&row).unwrap()
            );
        }
    }

    #[test]
    fn forest_round_trip_preserves_votes() {
        let ds = cat_ds();
        let forest = Forest::fit(
            &ds,
            &ForestConfig {
                n_trees: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let saved = SavedModel::new(Model::Forest(forest), &ds);
        let back = round_trip(&saved);
        assert_eq!(back.model.kind(), "forest");
        for r in (0..ds.n_rows()).step_by(19) {
            let row = ds.row(r);
            assert_eq!(
                back.model.predict_row(&row).unwrap(),
                saved.model.predict_row(&row).unwrap()
            );
        }
    }

    #[test]
    fn regression_model_round_trips() {
        let ds = generate_any(&SynthSpec::regression("serreg", 300, 4), 7);
        let tree = Udt::builder().fit(&ds).unwrap();
        let saved = SavedModel::new(Model::SingleTree(tree), &ds);
        let back = round_trip(&saved);
        let row = ds.row(5);
        assert_eq!(
            back.model.predict_row(&row).unwrap(),
            saved.model.predict_row(&row).unwrap()
        );
    }

    #[test]
    fn boosted_round_trip_preserves_predictions_for_both_tasks() {
        use crate::tree::boost::{Boosted, BoostedConfig};
        let cfg = BoostedConfig {
            n_rounds: 6,
            ..Default::default()
        };
        // Classification (one-vs-rest: 3 classes → 18 member trees).
        let ds = cat_ds();
        let boosted = Boosted::fit(&ds, &cfg).unwrap();
        let saved = SavedModel::new(Model::Boosted(boosted), &ds);
        let back = round_trip(&saved);
        assert_eq!(back.model.kind(), "boosted");
        match (&back.model, &saved.model) {
            (Model::Boosted(b), Model::Boosted(a)) => {
                assert_eq!(b.n_classes, a.n_classes);
                assert_eq!(b.n_rounds(), a.n_rounds());
                assert_eq!(b.base, a.base);
                assert_eq!(b.learning_rate, a.learning_rate);
            }
            _ => panic!("expected boosted"),
        }
        for r in (0..ds.n_rows()).step_by(17) {
            let row = ds.row(r);
            assert_eq!(
                back.model.predict_row(&row).unwrap(),
                saved.model.predict_row(&row).unwrap()
            );
        }
        // Regression.
        let reg = generate_any(&SynthSpec::regression("serboost", 300, 4), 11);
        let boosted = Boosted::fit(&reg, &cfg).unwrap();
        let saved = SavedModel::new(Model::Boosted(boosted), &reg);
        let back = round_trip(&saved);
        for r in (0..reg.n_rows()).step_by(13) {
            let row = reg.row(r);
            assert_eq!(
                back.model.predict_row(&row).unwrap(),
                saved.model.predict_row(&row).unwrap()
            );
        }
    }

    #[test]
    fn malformed_boosted_documents_are_typed_model_errors() {
        let tree = r#"{"task":"regression","n_features":1,"depth":1,
                       "nodes":[{"n":3,"d":1,"label":0.5}]}"#;
        for (name, doc) in [
            // Missing the boost block entirely.
            (
                "no boost block",
                format!(
                    r#"{{"format":"udt-model","version":1,"kind":"boosted",
                         "schema":{{"features":[{{"name":"f0","kind":"numeric"}}],"classes":[]}},
                         "interner":[],"trees":[{tree}]}}"#
                ),
            ),
            // Base arity disagrees with the class count.
            (
                "base arity",
                format!(
                    r#"{{"format":"udt-model","version":1,"kind":"boosted",
                         "schema":{{"features":[{{"name":"f0","kind":"numeric"}}],"classes":[]}},
                         "interner":[],"trees":[{tree},{tree},{tree}],
                         "boost":{{"task":"classification","n_classes":3,
                                   "learning_rate":0.1,"base":[0.0]}}}}"#
                ),
            ),
            // Tree count does not tile the score channels.
            (
                "tree tiling",
                format!(
                    r#"{{"format":"udt-model","version":1,"kind":"boosted",
                         "schema":{{"features":[{{"name":"f0","kind":"numeric"}}],"classes":[]}},
                         "interner":[],"trees":[{tree},{tree}],
                         "boost":{{"task":"classification","n_classes":3,
                                   "learning_rate":0.1,"base":[0.0,0.0,0.0]}}}}"#
                ),
            ),
            // Regression must carry no classes.
            (
                "regression classes",
                format!(
                    r#"{{"format":"udt-model","version":1,"kind":"boosted",
                         "schema":{{"features":[{{"name":"f0","kind":"numeric"}}],"classes":[]}},
                         "interner":[],"trees":[{tree}],
                         "boost":{{"task":"regression","n_classes":2,
                                   "learning_rate":0.1,"base":[0.0]}}}}"#
                ),
            ),
            // Non-positive learning rate.
            (
                "learning rate",
                format!(
                    r#"{{"format":"udt-model","version":1,"kind":"boosted",
                         "schema":{{"features":[{{"name":"f0","kind":"numeric"}}],"classes":[]}},
                         "interner":[],"trees":[{tree}],
                         "boost":{{"task":"regression","n_classes":0,
                                   "learning_rate":0.0,"base":[0.0]}}}}"#
                ),
            ),
        ] {
            let parsed = Json::parse(&doc).unwrap();
            assert!(
                matches!(SavedModel::from_json(&parsed), Err(UdtError::Model(_))),
                "{name}"
            );
        }
    }

    #[test]
    fn legacy_bare_tree_documents_still_load() {
        let ds = cat_ds();
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let legacy = tree_serialize::to_json(&tree, &ds.interner).to_pretty();
        let saved = SavedModel::from_json(&Json::parse(&legacy).unwrap()).unwrap();
        assert_eq!(saved.model.kind(), "single_tree");
        assert_eq!(saved.schema.n_features(), ds.n_features());
    }

    #[test]
    fn malformed_documents_are_typed_model_errors() {
        for doc in [
            "{}",
            r#"{"format":"udt-model"}"#,
            r#"{"format":"udt-model","version":99,"kind":"single_tree"}"#,
            r#"{"format":"not-a-model","version":1}"#,
            r#"{"format":"udt-model","version":1,"kind":"alien",
                "schema":{"features":[],"classes":[]},"interner":[]}"#,
            r#"{"format":"udt-model","version":1,"kind":"forest",
                "schema":{"features":[],"classes":[]},"interner":[],
                "trees":[],"n_classes":2}"#,
        ] {
            let parsed = Json::parse(doc).unwrap();
            assert!(
                matches!(SavedModel::from_json(&parsed), Err(UdtError::Model(_))),
                "{doc}"
            );
        }
    }
}
