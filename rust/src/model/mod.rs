//! The unified model surface: one way to train, predict, evaluate and
//! ship any UDT artifact.
//!
//! * [`Estimator`] — the `fit` / `predict_row` / `predict_batch` /
//!   `evaluate` contract implemented by [`Tree`] and [`Forest`].
//! * [`Udt::builder`] / [`Forest::builder`] — fluent, validating
//!   configuration builders replacing hand-rolled config literals.
//! * [`Model`] — a trained artifact of any family: a single tree, a
//!   Training-Only-Once tuned tree (the full tree plus its effective
//!   `(max_depth, min_split)`), or a bagged forest. The prediction server
//!   and CLI dispatch through it, so every family is servable.
//! * [`SavedModel`] — a [`Model`] bundled with its [`Schema`] and string
//!   interner; versioned JSON serialization lives in [`serialize`].
//!
//! ```no_run
//! use udt::data::synth::{generate_classification, SynthSpec};
//! use udt::selection::heuristic::ClassCriterion;
//! use udt::{Estimator, Udt};
//!
//! # fn main() -> udt::Result<()> {
//! let ds = generate_classification(&SynthSpec::classification("demo", 1000, 8, 3), 42);
//! let tree = Udt::builder()
//!     .criterion(ClassCriterion::Gini)
//!     .max_depth(8)
//!     .threads(8)
//!     .fit(&ds)?;
//! let quality = tree.evaluate(&ds)?;
//! println!("{:.3}", quality.headline());
//! # Ok(())
//! # }
//! ```

pub mod schema;
pub mod serialize;

pub use schema::{FeatureKind, Schema};

use crate::data::dataset::{Dataset, Labels, TaskKind};
use crate::data::interner::Interner;
use crate::data::value::Value;
use crate::error::{Result, UdtError};
use crate::selection::heuristic::ClassCriterion;
use crate::selection::split::SplitOp;
use crate::tree::boost::{Boosted, BoostedConfig};
use crate::tree::forest::{Forest, ForestConfig};
use crate::tree::{predict, require_task, Backend, NodeLabel, RegStrategy, TrainConfig, Tree};

/// Model quality on a dataset: accuracy or (MAE, RMSE).
#[derive(Debug, Clone, Copy)]
pub enum Quality {
    Accuracy(f64),
    Regression { mae: f64, rmse: f64 },
}

impl Quality {
    /// Scalar summary (accuracy, or RMSE for regression).
    pub fn headline(&self) -> f64 {
        match self {
            Quality::Accuracy(a) => *a,
            Quality::Regression { rmse, .. } => *rmse,
        }
    }
}

/// The single training/prediction contract every UDT model family
/// implements. `fit` takes the family's config; everything downstream —
/// row prediction, batch prediction, evaluation — is uniform.
pub trait Estimator: Sized {
    /// The family's training configuration ([`TrainConfig`],
    /// [`ForestConfig`], ...).
    type Config;

    /// Train on a dataset.
    fn fit(ds: &Dataset, config: &Self::Config) -> Result<Self>;

    /// Task kind the model was trained for.
    fn task(&self) -> TaskKind;

    /// Number of feature columns the model expects.
    fn n_features(&self) -> usize;

    /// Predict one materialized row. Errors on arity mismatch.
    fn predict_row(&self, row: &[Value]) -> Result<NodeLabel>;

    /// Predict a batch of rows. Errors on any arity mismatch.
    fn predict_batch(&self, rows: &[Vec<Value>]) -> Result<Vec<NodeLabel>> {
        rows.iter().map(|r| self.predict_row(r)).collect()
    }

    /// Quality over a whole dataset (accuracy, or MAE/RMSE).
    fn evaluate(&self, ds: &Dataset) -> Result<Quality>;
}

fn check_arity(expected: usize, got: usize) -> Result<()> {
    if expected == got {
        Ok(())
    } else {
        Err(UdtError::predict(format!(
            "expected {expected} features, got {got}"
        )))
    }
}

/// `Quality` wrapper over the shared `crate::tree::mae_rmse` fold.
fn regression_quality(pairs: impl Iterator<Item = (f64, f64)>) -> Quality {
    let (mae, rmse) = crate::tree::mae_rmse(pairs);
    Quality::Regression { mae, rmse }
}

/// Tree quality under prediction-time hyper-parameter caps (the
/// Training-Only-Once serving path uses non-trivial caps).
fn evaluate_tree(tree: &Tree, ds: &Dataset, max_depth: usize, min_split: usize) -> Result<Quality> {
    check_arity(tree.n_features, ds.n_features())?;
    require_task(tree.task, ds.task())?;
    let n = ds.n_rows();
    match ds.task() {
        TaskKind::Classification => {
            let correct = (0..n)
                .filter(|&r| {
                    predict::predict_ds(tree, ds, r, max_depth, min_split).as_class()
                        == Some(ds.labels.class(r))
                })
                .count();
            Ok(Quality::Accuracy(correct as f64 / n.max(1) as f64))
        }
        TaskKind::Regression => Ok(regression_quality((0..n).map(|r| {
            (
                predict::predict_ds(tree, ds, r, max_depth, min_split)
                    .as_value()
                    .unwrap_or(f64::NAN),
                ds.labels.target(r),
            )
        }))),
    }
}

impl Estimator for Tree {
    type Config = TrainConfig;

    fn fit(ds: &Dataset, config: &TrainConfig) -> Result<Tree> {
        Tree::fit(ds, config)
    }

    fn task(&self) -> TaskKind {
        self.task
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn predict_row(&self, row: &[Value]) -> Result<NodeLabel> {
        check_arity(self.n_features, row.len())?;
        Ok(predict::predict_row(self, row, usize::MAX, 0))
    }

    fn evaluate(&self, ds: &Dataset) -> Result<Quality> {
        evaluate_tree(self, ds, usize::MAX, 0)
    }
}

impl Estimator for Forest {
    type Config = ForestConfig;

    fn fit(ds: &Dataset, config: &ForestConfig) -> Result<Forest> {
        Forest::fit(ds, config)
    }

    fn task(&self) -> TaskKind {
        self.task
    }

    fn n_features(&self) -> usize {
        Forest::n_features(self)
    }

    fn predict_row(&self, row: &[Value]) -> Result<NodeLabel> {
        check_arity(Forest::n_features(self), row.len())?;
        Ok(self.predict_values(row))
    }

    /// Chunk-parallel over all cores (thread count never changes the
    /// predictions; see [`Forest::predict_batch_rows`]).
    fn predict_batch(&self, rows: &[Vec<Value>]) -> Result<Vec<NodeLabel>> {
        let n_features = Forest::n_features(self);
        for row in rows {
            check_arity(n_features, row.len())?;
        }
        Ok(self.predict_batch_rows(rows, 0))
    }

    fn evaluate(&self, ds: &Dataset) -> Result<Quality> {
        check_arity(Forest::n_features(self), ds.n_features())?;
        require_task(self.task, ds.task())?;
        match ds.task() {
            TaskKind::Classification => {
                let all: Vec<u32> = (0..ds.n_rows() as u32).collect();
                Ok(Quality::Accuracy(self.accuracy_rows(ds, &all)?))
            }
            TaskKind::Regression => Ok(regression_quality((0..ds.n_rows()).map(|r| {
                (
                    self.predict_ds(ds, r).as_value().unwrap_or(f64::NAN),
                    ds.labels.target(r),
                )
            }))),
        }
    }
}

impl Estimator for Boosted {
    type Config = BoostedConfig;

    fn fit(ds: &Dataset, config: &BoostedConfig) -> Result<Boosted> {
        Boosted::fit(ds, config)
    }

    fn task(&self) -> TaskKind {
        self.task
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn predict_row(&self, row: &[Value]) -> Result<NodeLabel> {
        check_arity(self.n_features, row.len())?;
        Ok(self.predict_values(row))
    }

    /// Chunk-parallel over all cores (thread count never changes the
    /// predictions; see [`Boosted::predict_batch_rows`]).
    fn predict_batch(&self, rows: &[Vec<Value>]) -> Result<Vec<NodeLabel>> {
        for row in rows {
            check_arity(self.n_features, row.len())?;
        }
        Ok(self.predict_batch_rows(rows, 0))
    }

    fn evaluate(&self, ds: &Dataset) -> Result<Quality> {
        check_arity(self.n_features, ds.n_features())?;
        require_task(self.task, ds.task())?;
        match ds.task() {
            TaskKind::Classification => {
                let all: Vec<u32> = (0..ds.n_rows() as u32).collect();
                Ok(Quality::Accuracy(self.accuracy_rows(ds, &all)?))
            }
            TaskKind::Regression => {
                let all: Vec<u32> = (0..ds.n_rows() as u32).collect();
                let (mae, rmse) = self.regression_error(ds, &all)?;
                Ok(Quality::Regression { mae, rmse })
            }
        }
    }
}

/// Entry point of the fluent single-tree API: `Udt::builder()`.
pub struct Udt;

impl Udt {
    /// A validating builder over [`TrainConfig`].
    pub fn builder() -> UdtBuilder {
        UdtBuilder::new()
    }
}

/// Fluent, validating builder for single-tree training.
///
/// Invalid settings surface as [`UdtError::InvalidConfig`] from
/// [`build`](UdtBuilder::build) / [`fit`](UdtBuilder::fit) instead of
/// panicking mid-training.
#[derive(Debug, Clone, Default)]
pub struct UdtBuilder {
    cfg: TrainConfig,
}

impl UdtBuilder {
    pub fn new() -> Self {
        Self {
            cfg: TrainConfig::default(),
        }
    }

    /// Classification split criterion (ignored for regression).
    pub fn criterion(mut self, c: ClassCriterion) -> Self {
        self.cfg.criterion = c;
        self
    }

    /// Maximum tree depth (root = 1). Must be ≥ 1.
    pub fn max_depth(mut self, d: usize) -> Self {
        self.cfg.max_depth = d;
        self
    }

    /// Minimum node size eligible for splitting. Must be ≥ 2.
    pub fn min_samples_split(mut self, m: usize) -> Self {
        self.cfg.min_samples_split = m;
        self
    }

    /// Minimum heuristic gain over the parent to accept a split.
    pub fn min_gain(mut self, g: f64) -> Self {
        self.cfg.min_gain = g;
        self
    }

    /// Selection engine.
    pub fn backend(mut self, b: Backend) -> Self {
        self.cfg.backend = b;
        self
    }

    /// Regression split strategy.
    pub fn reg_strategy(mut self, s: RegStrategy) -> Self {
        self.cfg.reg_strategy = s;
        self
    }

    /// Worker threads (0 = all cores, 1 = sequential).
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.n_threads = n;
        self
    }

    /// Validate and return the underlying [`TrainConfig`].
    pub fn build(self) -> Result<TrainConfig> {
        if self.cfg.max_depth < 1 {
            return Err(UdtError::invalid_config("max_depth must be >= 1"));
        }
        if self.cfg.min_samples_split < 2 {
            return Err(UdtError::invalid_config(
                "min_samples_split must be >= 2 (a 1-row node cannot split)",
            ));
        }
        if !self.cfg.min_gain.is_finite() {
            return Err(UdtError::invalid_config("min_gain must be finite"));
        }
        Ok(self.cfg)
    }

    /// Validate, then train a [`Tree`] on `ds`.
    pub fn fit(self, ds: &Dataset) -> Result<Tree> {
        let cfg = self.build()?;
        Tree::fit(ds, &cfg)
    }
}

impl Forest {
    /// A validating builder over [`ForestConfig`].
    pub fn builder() -> ForestBuilder {
        ForestBuilder::new()
    }
}

/// Fluent, validating builder for bagged-forest training.
#[derive(Debug, Clone, Default)]
pub struct ForestBuilder {
    cfg: ForestConfig,
}

impl ForestBuilder {
    pub fn new() -> Self {
        Self {
            cfg: ForestConfig::default(),
        }
    }

    /// Ensemble size. Must be ≥ 1.
    pub fn n_trees(mut self, n: usize) -> Self {
        self.cfg.n_trees = n;
        self
    }

    /// Fraction of features each tree sees, in (0, 1].
    pub fn feature_frac(mut self, f: f64) -> Self {
        self.cfg.feature_frac = f;
        self
    }

    /// Subsample fraction per tree (without replacement), in (0, 1].
    pub fn sample_frac(mut self, f: f64) -> Self {
        self.cfg.sample_frac = f;
        self
    }

    /// Bagging seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.cfg.seed = s;
        self
    }

    /// Per-tree training configuration (from [`Udt::builder`]).
    pub fn tree(mut self, cfg: TrainConfig) -> Self {
        self.cfg.tree = cfg;
        self
    }

    /// Validate and return the underlying [`ForestConfig`].
    pub fn build(self) -> Result<ForestConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }

    /// Validate, then train a [`Forest`] on `ds`.
    pub fn fit(self, ds: &Dataset) -> Result<Forest> {
        let cfg = self.build()?;
        Forest::fit(ds, &cfg)
    }
}

/// A trained artifact of any family, serving-ready.
#[derive(Debug, Clone)]
pub enum Model {
    /// A plain decision tree.
    SingleTree(Tree),
    /// A full tree plus the Training-Only-Once effective hyper-parameters;
    /// predictions stop at `max_depth` / nodes smaller than `min_split`
    /// exactly as the tuner evaluated them (paper Algorithm 7).
    TunedTree {
        tree: Tree,
        max_depth: usize,
        min_split: usize,
    },
    /// A bagged ensemble.
    Forest(Forest),
    /// A gradient-boosted ensemble (see [`crate::tree::boost`]).
    Boosted(Boosted),
}

impl Model {
    /// Stable serialization tag of the family.
    pub fn kind(&self) -> &'static str {
        match self {
            Model::SingleTree(_) => "single_tree",
            Model::TunedTree { .. } => "tuned_tree",
            Model::Forest(_) => "forest",
            Model::Boosted(_) => "boosted",
        }
    }

    pub fn task(&self) -> TaskKind {
        match self {
            Model::SingleTree(t) => t.task,
            Model::TunedTree { tree, .. } => tree.task,
            Model::Forest(f) => f.task,
            Model::Boosted(b) => b.task,
        }
    }

    pub fn n_features(&self) -> usize {
        match self {
            Model::SingleTree(t) => t.n_features,
            Model::TunedTree { tree, .. } => tree.n_features,
            Model::Forest(f) => f.n_features(),
            Model::Boosted(b) => b.n_features,
        }
    }

    /// Total node count (across all member trees for an ensemble).
    pub fn n_nodes(&self) -> usize {
        match self {
            Model::SingleTree(t) => t.n_nodes(),
            Model::TunedTree { tree, .. } => tree.n_nodes(),
            Model::Forest(f) => f.n_nodes(),
            Model::Boosted(b) => b.n_nodes(),
        }
    }

    /// Predict one materialized row. Errors on arity mismatch.
    pub fn predict_row(&self, row: &[Value]) -> Result<NodeLabel> {
        check_arity(self.n_features(), row.len())?;
        Ok(match self {
            Model::SingleTree(t) => predict::predict_row(t, row, usize::MAX, 0),
            Model::TunedTree {
                tree,
                max_depth,
                min_split,
            } => predict::predict_row(tree, row, *max_depth, *min_split),
            Model::Forest(f) => f.predict_values(row),
            Model::Boosted(b) => b.predict_values(row),
        })
    }

    /// Predict a batch. The family dispatch happens once per batch, not
    /// once per row — the serving hot path.
    pub fn predict_batch(&self, rows: &[Vec<Value>]) -> Result<Vec<NodeLabel>> {
        let n_features = self.n_features();
        for row in rows {
            check_arity(n_features, row.len())?;
        }
        Ok(match self {
            Model::SingleTree(t) => rows
                .iter()
                .map(|r| predict::predict_row(t, r, usize::MAX, 0))
                .collect(),
            Model::TunedTree {
                tree,
                max_depth,
                min_split,
            } => rows
                .iter()
                .map(|r| predict::predict_row(tree, r, *max_depth, *min_split))
                .collect(),
            Model::Forest(f) => f.predict_batch_rows(rows, 0),
            Model::Boosted(b) => b.predict_batch_rows(rows, 0),
        })
    }

    /// Flatten into a [`CompiledModel`] (struct-of-arrays node tables,
    /// tuned caps and the interner's categorical lookups baked in — see
    /// [`crate::inference`]). `interner` must be the one the model's
    /// categorical operands were interned with;
    /// [`SavedModel::compile`] passes the bundled one.
    pub fn compile(&self, interner: &Interner) -> Result<crate::inference::CompiledModel> {
        crate::inference::CompiledModel::compile(self, interner)
    }

    /// Quality over a dataset, honoring tuned caps.
    pub fn evaluate(&self, ds: &Dataset) -> Result<Quality> {
        match self {
            Model::SingleTree(t) => evaluate_tree(t, ds, usize::MAX, 0),
            Model::TunedTree {
                tree,
                max_depth,
                min_split,
            } => evaluate_tree(tree, ds, *max_depth, *min_split),
            Model::Forest(f) => f.evaluate(ds),
            Model::Boosted(b) => b.evaluate(ds),
        }
    }

    fn trees_mut(&mut self) -> Vec<&mut Tree> {
        match self {
            Model::SingleTree(t) => vec![t],
            Model::TunedTree { tree, .. } => vec![tree],
            Model::Forest(f) => f.trees.iter_mut().collect(),
            Model::Boosted(b) => b.trees.iter_mut().collect(),
        }
    }

    /// Remap categorical split operands from `from`'s id space into `to`'s
    /// (interning unseen names). Lets a loaded model predict over a
    /// dataset that interned its strings independently.
    pub fn reintern(&mut self, from: &Interner, to: &mut Interner) -> Result<()> {
        for tree in self.trees_mut() {
            for node in &mut tree.nodes {
                if let Some(split) = &mut node.split {
                    if let SplitOp::Eq(id) = split.op {
                        let name = from.names().get(id.0 as usize).ok_or_else(|| {
                            UdtError::model(format!("categorical operand {} out of range", id.0))
                        })?;
                        split.op = SplitOp::Eq(to.intern(name));
                    }
                }
            }
        }
        Ok(())
    }
}

/// A [`Model`] with everything serving needs: the [`Schema`] and the
/// categorical string interner it was trained with.
#[derive(Debug, Clone)]
pub struct SavedModel {
    pub model: Model,
    pub schema: Schema,
    pub interner: Interner,
}

impl SavedModel {
    /// Bundle a model with the schema/interner of its training dataset.
    pub fn new(model: Model, ds: &Dataset) -> SavedModel {
        SavedModel {
            model,
            schema: Schema::of(ds),
            interner: (*ds.interner).clone(),
        }
    }

    /// Flatten the bundled model into a serving-ready
    /// [`crate::inference::CompiledModel`] using the bundled interner.
    pub fn compile(&self) -> Result<crate::inference::CompiledModel> {
        self.model.compile(&self.interner)
    }

    /// Remap the model's categorical operands into `target`'s id space
    /// (e.g. the interner of a freshly-loaded evaluation CSV).
    pub fn align_to(&mut self, target: &mut Interner) -> Result<()> {
        let from = std::mem::take(&mut self.interner);
        self.model.reintern(&from, target)?;
        self.interner = target.clone();
        Ok(())
    }

    /// Remap `ds`'s class-label ids into the model's class-id space using
    /// the bundled class names. A CSV assigns ids by first appearance, so
    /// an evaluation file listing classes in a different order would
    /// otherwise score a correct model as wrong. No-op for regression
    /// models or when either side carries no class names; classes the
    /// model never saw get fresh ids past its range (they can never match
    /// a prediction, which is the correct "always wrong" semantics).
    pub fn align_labels(&self, ds: &mut Dataset) {
        if self.model.task() != TaskKind::Classification
            || self.schema.class_names.is_empty()
            || ds.class_names.is_empty()
        {
            return;
        }
        let mut names = self.schema.class_names.clone();
        let map: Vec<u16> = ds
            .class_names
            .iter()
            .map(|n| match names.iter().position(|m| m == n) {
                Some(i) => i as u16,
                None => {
                    names.push(n.clone());
                    (names.len() - 1) as u16
                }
            })
            .collect();
        if let Labels::Class { ids, n_classes } = &mut ds.labels {
            for id in ids.iter_mut() {
                *id = map.get(*id as usize).copied().unwrap_or(*id);
            }
            *n_classes = names.len();
        }
        ds.class_names = std::sync::Arc::new(names);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_any, generate_classification, SynthSpec};

    fn small_ds() -> Dataset {
        let mut spec = SynthSpec::classification("m", 600, 5, 3);
        spec.cat_frac = 0.3;
        generate_classification(&spec, 91)
    }

    #[test]
    fn builder_produces_working_tree() {
        let ds = small_ds();
        let tree = Udt::builder()
            .criterion(ClassCriterion::Gini)
            .max_depth(8)
            .threads(1)
            .fit(&ds)
            .unwrap();
        assert!(tree.depth <= 8);
        match tree.evaluate(&ds).unwrap() {
            Quality::Accuracy(a) => assert!(a > 0.5, "acc {a}"),
            _ => panic!("expected accuracy"),
        }
    }

    #[test]
    fn builder_rejects_bad_configs() {
        assert!(matches!(
            Udt::builder().max_depth(0).build(),
            Err(UdtError::InvalidConfig(_))
        ));
        assert!(matches!(
            Udt::builder().min_samples_split(1).build(),
            Err(UdtError::InvalidConfig(_))
        ));
        assert!(matches!(
            Udt::builder().min_gain(f64::NAN).build(),
            Err(UdtError::InvalidConfig(_))
        ));
        assert!(matches!(
            Forest::builder().n_trees(0).build(),
            Err(UdtError::InvalidConfig(_))
        ));
    }

    #[test]
    fn model_families_predict_consistently() {
        let ds = small_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let forest = Forest::builder().n_trees(3).fit(&ds).unwrap();
        let models = [
            Model::SingleTree(tree.clone()),
            Model::TunedTree {
                tree,
                max_depth: 4,
                min_split: 10,
            },
            Model::Forest(forest),
        ];
        let rows: Vec<Vec<Value>> = (0..20).map(|r| ds.row(r)).collect();
        for m in &models {
            let batch = m.predict_batch(&rows).unwrap();
            assert_eq!(batch.len(), rows.len());
            for (row, label) in rows.iter().zip(&batch) {
                assert_eq!(m.predict_row(row).unwrap(), *label, "{}", m.kind());
            }
        }
    }

    #[test]
    fn tuned_tree_honors_caps() {
        let ds = small_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let capped = Model::TunedTree {
            tree: tree.clone(),
            max_depth: 1,
            min_split: 0,
        };
        let root_label = tree.nodes[0].label;
        for r in (0..ds.n_rows()).step_by(41) {
            assert_eq!(capped.predict_row(&ds.row(r)).unwrap(), root_label);
        }
    }

    #[test]
    fn arity_mismatch_is_a_typed_error() {
        let ds = small_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let model = Model::SingleTree(tree);
        assert!(matches!(
            model.predict_row(&[Value::Num(1.0)]),
            Err(UdtError::Predict(_))
        ));
        assert!(matches!(
            model.predict_batch(&[vec![Value::Num(1.0)]]),
            Err(UdtError::Predict(_))
        ));
    }

    #[test]
    fn align_labels_remaps_permuted_class_ids() {
        use crate::data::column::Column;
        // f0 in 0..10; label = f0 >= 5.
        let mk = |names: [&str; 2], flip: bool| {
            let vals: Vec<Value> = (0..10).map(|i| Value::Num(i as f64)).collect();
            let ids: Vec<u16> = (0..10).map(|i| ((i >= 5) ^ flip) as u16).collect();
            let mut ds = Dataset::new(
                "al",
                vec![Column::new("f0", vals)],
                Labels::Class { ids, n_classes: 2 },
                Interner::new(),
            )
            .unwrap();
            ds.class_names =
                std::sync::Arc::new(names.iter().map(|s| s.to_string()).collect());
            ds
        };
        // Trained where "neg"=0, "pos"=1.
        let train_ds = mk(["neg", "pos"], false);
        let tree = Udt::builder().fit(&train_ds).unwrap();
        let saved = SavedModel::new(Model::SingleTree(tree), &train_ds);
        // Same data, but the eval file listed "pos" first → ids flipped.
        let mut eval_ds = mk(["pos", "neg"], true);
        // Without alignment every comparison is inverted.
        match saved.model.evaluate(&eval_ds).unwrap() {
            Quality::Accuracy(a) => assert!(a < 0.5, "pre-align acc {a}"),
            _ => panic!("expected accuracy"),
        }
        saved.align_labels(&mut eval_ds);
        match saved.model.evaluate(&eval_ds).unwrap() {
            Quality::Accuracy(a) => assert_eq!(a, 1.0, "post-align acc {a}"),
            _ => panic!("expected accuracy"),
        }
    }

    #[test]
    fn evaluate_task_mismatch_is_typed() {
        let class_ds = small_ds();
        let reg_ds = generate_any(&SynthSpec::regression("r", 300, 5), 3);
        let tree = Udt::builder().fit(&class_ds).unwrap();
        assert!(matches!(
            tree.evaluate(&reg_ds),
            Err(UdtError::TaskMismatch { .. })
        ));
    }
}
