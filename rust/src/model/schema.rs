//! Model schema: the dataset-shape metadata bundled with every serialized
//! model so serving never needs the training data — feature names and
//! kinds, plus human-readable class names.

use crate::data::dataset::Dataset;
use crate::error::{Result, UdtError};
use crate::util::json::Json;

/// What a feature column held at training time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureKind {
    /// Only numeric cells (plus missing).
    Numeric,
    /// Only categorical cells (plus missing).
    Categorical,
    /// Hybrid: numeric and categorical cells in the same column.
    Mixed,
    /// Unknown composition (legacy models without a schema).
    Unknown,
}

impl FeatureKind {
    pub fn name(&self) -> &'static str {
        match self {
            FeatureKind::Numeric => "numeric",
            FeatureKind::Categorical => "categorical",
            FeatureKind::Mixed => "mixed",
            FeatureKind::Unknown => "unknown",
        }
    }

    pub fn parse(s: &str) -> Option<FeatureKind> {
        match s {
            "numeric" => Some(FeatureKind::Numeric),
            "categorical" => Some(FeatureKind::Categorical),
            "mixed" => Some(FeatureKind::Mixed),
            "unknown" => Some(FeatureKind::Unknown),
            _ => None,
        }
    }
}

/// The dataset shape a model was trained against.
#[derive(Debug, Clone)]
pub struct Schema {
    /// One name per feature column, in model feature order.
    pub feature_names: Vec<String>,
    /// One kind per feature column, parallel to `feature_names`.
    pub feature_kinds: Vec<FeatureKind>,
    /// Human-readable class names (classification; may be empty when the
    /// training labels were already numeric).
    pub class_names: Vec<String>,
}

impl Schema {
    /// Derive the schema of a dataset.
    pub fn of(ds: &Dataset) -> Schema {
        let feature_names = ds.columns.iter().map(|c| c.name.clone()).collect();
        let feature_kinds = ds
            .columns
            .iter()
            .map(|c| {
                let s = c.stats();
                match (s.n_num > 0, s.n_cat > 0) {
                    (true, true) => FeatureKind::Mixed,
                    (true, false) => FeatureKind::Numeric,
                    (false, true) => FeatureKind::Categorical,
                    (false, false) => FeatureKind::Unknown,
                }
            })
            .collect();
        Schema {
            feature_names,
            feature_kinds,
            class_names: (*ds.class_names).clone(),
        }
    }

    /// Placeholder schema for legacy model documents (`f0`, `f1`, ...).
    pub fn unnamed(n_features: usize) -> Schema {
        Schema {
            feature_names: (0..n_features).map(|i| format!("f{i}")).collect(),
            feature_kinds: vec![FeatureKind::Unknown; n_features],
            class_names: Vec::new(),
        }
    }

    pub fn n_features(&self) -> usize {
        self.feature_names.len()
    }

    /// Human-readable name of a class id, when known.
    pub fn class_name(&self, class: u16) -> Option<&str> {
        self.class_names.get(class as usize).map(|s| s.as_str())
    }

    pub fn to_json(&self) -> Json {
        let features: Vec<Json> = self
            .feature_names
            .iter()
            .zip(&self.feature_kinds)
            .map(|(name, kind)| {
                Json::obj(vec![
                    ("name", Json::Str(name.clone())),
                    ("kind", Json::Str(kind.name().to_string())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("features", Json::Arr(features)),
            (
                "classes",
                Json::Arr(
                    self.class_names
                        .iter()
                        .map(|c| Json::Str(c.clone()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(json: &Json) -> Result<Schema> {
        let features = json
            .get("features")
            .and_then(Json::as_arr)
            .ok_or_else(|| UdtError::model("schema: missing `features`"))?;
        let mut feature_names = Vec::with_capacity(features.len());
        let mut feature_kinds = Vec::with_capacity(features.len());
        for (i, f) in features.iter().enumerate() {
            let name = f
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| UdtError::model(format!("schema: feature {i} missing `name`")))?;
            let kind = f
                .get("kind")
                .and_then(Json::as_str)
                .and_then(FeatureKind::parse)
                .ok_or_else(|| UdtError::model(format!("schema: feature {i} bad `kind`")))?;
            feature_names.push(name.to_string());
            feature_kinds.push(kind);
        }
        let classes = json
            .get("classes")
            .and_then(Json::as_arr)
            .ok_or_else(|| UdtError::model("schema: missing `classes`"))?;
        let class_names = classes
            .iter()
            .map(|c| {
                c.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| UdtError::model("schema: class names must be strings"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Schema {
            feature_names,
            feature_kinds,
            class_names,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_classification, SynthSpec};

    #[test]
    fn schema_round_trips_through_json() {
        let mut spec = SynthSpec::classification("s", 200, 6, 3);
        spec.cat_frac = 0.3;
        let ds = generate_classification(&spec, 7);
        let schema = Schema::of(&ds);
        assert_eq!(schema.n_features(), 6);
        let back = Schema::from_json(&schema.to_json()).unwrap();
        assert_eq!(back.feature_names, schema.feature_names);
        assert_eq!(back.feature_kinds, schema.feature_kinds);
        assert_eq!(back.class_names, schema.class_names);
    }

    #[test]
    fn rejects_malformed_schema() {
        assert!(Schema::from_json(&Json::parse("{}").unwrap()).is_err());
        let bad = r#"{"features":[{"name":"a","kind":"nope"}],"classes":[]}"#;
        assert!(Schema::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn unnamed_generates_placeholders() {
        let s = Schema::unnamed(3);
        assert_eq!(s.feature_names, vec!["f0", "f1", "f2"]);
        assert_eq!(s.feature_kinds, vec![FeatureKind::Unknown; 3]);
    }
}
