//! Benchmark framework (the offline image ships no criterion): warmup,
//! repeated timed runs, mean/stddev/min, and table/CSV renderers shared by
//! every `rust/benches/*` target so each paper table regenerates with the
//! same formatting.

pub mod table5;

use crate::util::json::Json;
use crate::util::timer::Timer;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Measurement of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub runs: Vec<f64>, // milliseconds
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.runs.iter().sum::<f64>() / self.runs.len().max(1) as f64
    }

    pub fn min_ms(&self) -> f64 {
        self.runs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Interpolated percentile of the runs (`p` in `[0, 1]`; 0.5 = p50
    /// median latency). NaN on an empty measurement.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.runs.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.runs.clone();
        // ANALYZE-ALLOW(no-unwrap): run times come from Duration::as_secs_f64, never NaN
        sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        sorted[lo] + (sorted[hi] - sorted[lo]) * frac
    }

    pub fn stddev_ms(&self) -> f64 {
        let n = self.runs.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.mean_ms();
        let var = self
            .runs
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }
}

/// Bench runner configuration (env-overridable for CI).
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: usize,
    pub runs: usize,
    /// Global scale factor applied by workloads to the paper's dataset
    /// sizes (UDT_BENCH_SCALE env; 1.0 = paper-sized).
    pub scale: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: 1,
            runs: 3,
            scale: 1.0,
        }
    }
}

impl BenchConfig {
    /// Read from environment: UDT_BENCH_RUNS, UDT_BENCH_WARMUP,
    /// UDT_BENCH_SCALE.
    pub fn from_env() -> Self {
        let mut c = Self::default();
        if let Ok(v) = std::env::var("UDT_BENCH_RUNS") {
            if let Ok(n) = v.parse() {
                c.runs = n;
            }
        }
        if let Ok(v) = std::env::var("UDT_BENCH_WARMUP") {
            if let Ok(n) = v.parse() {
                c.warmup = n;
            }
        }
        if let Ok(v) = std::env::var("UDT_BENCH_SCALE") {
            if let Ok(s) = v.parse() {
                c.scale = s;
            }
        }
        c
    }
}

/// Time `f` under the config; `f` runs `warmup + runs` times.
pub fn bench(name: &str, config: &BenchConfig, mut f: impl FnMut()) -> Measurement {
    for _ in 0..config.warmup {
        f();
    }
    let mut runs = Vec::with_capacity(config.runs);
    for _ in 0..config.runs {
        let t = Timer::start();
        f();
        runs.push(t.ms());
    }
    Measurement {
        name: name.to_string(),
        runs,
    }
}

/// Time a single invocation (for expensive end-to-end cases).
pub fn bench_once(name: &str, f: impl FnOnce()) -> Measurement {
    let t = Timer::start();
    f();
    Measurement {
        name: name.to_string(),
        runs: vec![t.ms()],
    }
}

/// Fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (for figure series).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Write a machine-readable perf artifact `BENCH_<name>.json` so the
/// repository's perf trajectory is tracked PR-over-PR.
///
/// Location: `$UDT_BENCH_DIR` when set, else the repository root (the
/// parent of this crate's manifest directory). Returns the path written.
pub fn write_bench_json(name: &str, payload: &Json) -> std::io::Result<PathBuf> {
    let dir = match std::env::var("UDT_BENCH_DIR") {
        Ok(d) => PathBuf::from(d),
        Err(_) => Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(Path::to_path_buf)
            .unwrap_or_else(|| PathBuf::from(".")),
    };
    write_bench_json_to(&dir, name, payload)
}

/// [`write_bench_json`] with an explicit directory (tests use this to
/// avoid touching the process environment).
pub fn write_bench_json_to(dir: &Path, name: &str, payload: &Json) -> std::io::Result<PathBuf> {
    let path = dir.join(format!("BENCH_{name}.json"));
    let mut text = payload.to_pretty();
    text.push('\n');
    std::fs::write(&path, text)?;
    Ok(path)
}

/// Format milliseconds compactly for table cells.
pub fn fmt_ms(ms: f64) -> String {
    if ms < 1.0 {
        format!("{ms:.3}")
    } else if ms < 100.0 {
        format!("{ms:.2}")
    } else {
        format!("{ms:.0}")
    }
}

/// Sleep-free busy-wait used by self-tests.
#[doc(hidden)]
pub fn spin_for(d: Duration) {
    let t = Timer::start();
    while t.elapsed() < d {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_stats() {
        let m = Measurement {
            name: "x".into(),
            runs: vec![1.0, 2.0, 3.0],
        };
        assert!((m.mean_ms() - 2.0).abs() < 1e-12);
        assert_eq!(m.min_ms(), 1.0);
        assert!((m.stddev_ms() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let m = Measurement {
            name: "p".into(),
            runs: vec![4.0, 1.0, 3.0, 2.0],
        };
        assert_eq!(m.percentile_ms(0.0), 1.0);
        assert_eq!(m.percentile_ms(1.0), 4.0);
        assert!((m.percentile_ms(0.5) - 2.5).abs() < 1e-12);
        let empty = Measurement {
            name: "e".into(),
            runs: vec![],
        };
        assert!(empty.percentile_ms(0.5).is_nan());
    }

    #[test]
    fn bench_runs_requested_times() {
        let mut count = 0;
        let cfg = BenchConfig {
            warmup: 2,
            runs: 5,
            scale: 1.0,
        };
        let m = bench("t", &cfg, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(m.runs.len(), 5);
    }

    #[test]
    fn bench_measures_time() {
        let cfg = BenchConfig {
            warmup: 0,
            runs: 2,
            scale: 1.0,
        };
        let m = bench("spin", &cfg, || spin_for(Duration::from_millis(3)));
        assert!(m.min_ms() >= 2.5, "{:?}", m.runs);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "ms"]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["long-name".into(), "10".into()]);
        let s = t.render();
        assert!(s.contains("long-name"));
        let csv = t.to_csv();
        assert!(csv.starts_with("name,ms\n"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bench_json_artifact_round_trips() {
        let dir = std::env::temp_dir().join("udt_bench_selftest");
        std::fs::create_dir_all(&dir).unwrap();
        let payload = Json::obj(vec![
            ("bench", Json::Str("selftest".into())),
            ("train_ms", Json::Num(12.5)),
        ]);
        let path = write_bench_json_to(&dir, "selftest", &payload).unwrap();
        assert_eq!(
            path.file_name().and_then(|n| n.to_str()),
            Some("BENCH_selftest.json")
        );
        let text = std::fs::read_to_string(&path).unwrap();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("train_ms").and_then(Json::as_f64), Some(12.5));
        assert_eq!(
            back.get("bench").and_then(Json::as_str),
            Some("selftest")
        );
    }
}
