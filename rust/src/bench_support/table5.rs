//! Shared workload for paper Table 5 / Figure 1: generic vs Superfast
//! Selection on a single feature of a credit-card-fraud-shaped dataset
//! (1M × 7, numeric-heavy, 2 classes). Used by the `table5` bench target
//! and the `udt bench-selection` subcommand.

use super::{fmt_ms, Table};
use crate::data::synth::{generate_classification, registry, SynthSpec};
use crate::selection::generic::best_split_on_feat_generic;
use crate::selection::heuristic::{ClassCriterion, Criterion};
use crate::selection::superfast::{best_split_on_feat, FeatureView, LabelsView};
use crate::util::timer::Timer;

/// One measured size point.
#[derive(Debug, Clone)]
pub struct Point {
    pub size: usize,
    pub generic_ms: f64,
    pub superfast_ms: f64,
    pub agree: bool,
}

/// The workload spec (credit-card-fraud shape, numeric feature 0).
fn workload_spec(n_rows: usize) -> SynthSpec {
    let mut spec = registry::find("credit_card_fraud")
        // ANALYZE-ALLOW(no-unwrap): "credit_card_fraud" is a registry constant
        .expect("registered")
        .spec
        .clone();
    spec.n_rows = n_rows;
    // A purely numeric measured feature keeps the comparison about the
    // selection algorithms (as in the paper's single-feature experiment);
    // unique-value count N grows with M via the cardinality knob.
    spec.cat_frac = 0.0;
    spec.hybrid_frac = 0.0;
    spec.missing_frac = 0.0;
    spec.numeric_cardinality = (n_rows / 10).max(64);
    spec
}

/// Measure one size (averaging `runs` runs of each engine).
pub fn measure(size: usize, runs: usize, seed: u64) -> Point {
    let ds = generate_classification(&workload_spec(size), seed);
    let col = &ds.columns[0];
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let sorted = col.sorted_numeric();
    let view = FeatureView::new(0, col, &rows, &sorted.0, &sorted.1);
    let labels = LabelsView::from_labels(&ds.labels);
    let criterion = Criterion::Class(ClassCriterion::InfoGain);

    let mut generic_ms = 0.0;
    let mut superfast_ms = 0.0;
    let mut fast_result = None;
    let mut slow_result = None;
    for _ in 0..runs.max(1) {
        let t = Timer::start();
        slow_result = best_split_on_feat_generic(&view, &labels, criterion);
        generic_ms += t.ms();
        let t = Timer::start();
        fast_result = best_split_on_feat(&view, &labels, criterion);
        superfast_ms += t.ms();
    }
    let agree = match (fast_result, slow_result) {
        (Some(a), Some(b)) => (a.score - b.score).abs() < 1e-9 && a.op == b.op,
        (None, None) => true,
        _ => false,
    };
    Point {
        size,
        generic_ms: generic_ms / runs.max(1) as f64,
        superfast_ms: superfast_ms / runs.max(1) as f64,
        agree,
    }
}

/// Run the full sweep and render the paper's table layout.
pub fn run(sizes: &[usize], runs: usize, seed: u64) -> Table {
    let mut table = Table::new(&["data size", "generic(ms)", "superfast(ms)", "speedup", "agree"]);
    for &size in sizes {
        let p = measure(size, runs, seed);
        table.row(vec![
            format!("{}K", size / 1000),
            fmt_ms(p.generic_ms),
            fmt_ms(p.superfast_ms),
            format!("{:.0}x", p.generic_ms / p.superfast_ms.max(1e-9)),
            p.agree.to_string(),
        ]);
    }
    table
}

/// The paper's size grid (10K..100K).
pub fn paper_sizes() -> Vec<usize> {
    (1..=10).map(|i| i * 10_000).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engines_agree_and_superfast_wins_at_scale() {
        let p = measure(20_000, 1, 7);
        assert!(p.agree, "engines disagree");
        assert!(
            p.generic_ms > p.superfast_ms,
            "generic {} !> superfast {}",
            p.generic_ms,
            p.superfast_ms
        );
    }

    #[test]
    fn table_has_row_per_size() {
        let t = run(&[1000, 2000], 1, 3);
        let rendered = t.render();
        assert!(rendered.contains("1K") && rendered.contains("2K"));
    }
}
