//! Columnar prediction input: the [`RowFrame`].
//!
//! A frame is a thin view over the same typed columnar store training
//! uses — each feature column is a
//! [`ColumnData`](crate::data::column_data::ColumnData) (dense `f64` /
//! `u32` lanes + kind masks, specialized per content) plus a frame-local
//! string interner for categorical cells. There is no frame-specific
//! cell representation left: [`RowFrame::from_dataset`] **shares** the
//! dataset's `Arc` lanes and interner outright (zero copy), while the
//! builder / JSON / CSV constructors assemble fresh lanes through the
//! same [`ColumnShard`] sink the ingest pipeline uses.
//!
//! A [`super::CompiledModel`] translates frame-local category ids into
//! its own baked operand space once per `predict_frame` call, so the
//! traversal inner loop is pure integer compares.
//!
//! Frames build once from rows ([`RowFrameBuilder`]), JSON arrays
//! ([`RowFrame::from_json_rows`] / [`RowFrame::from_json_lines`]), CSV
//! text ([`RowFrame::from_csv_str`], routed through the one streaming
//! parser in `data/csv.rs`) or a [`Dataset`] view
//! ([`RowFrame::from_dataset`]).

use crate::data::column_data::{ColumnData, ColumnShard};
use crate::data::dataset::Dataset;
use crate::data::interner::Interner;
use crate::data::value::Value;
use crate::error::{Result, UdtError};
use crate::util::json::Json;
use std::sync::Arc;

/// The typed column storage frames share with the training data layer.
pub type FrameColumn = ColumnData;

/// Bit-per-row validity/kind mask (re-exported from the data layer).
pub type ValidityMask = crate::data::column_data::Bitmask;

/// One raw input cell handed to the [`RowFrameBuilder`].
#[derive(Debug, Clone, Copy)]
pub enum Cell<'a> {
    Num(f64),
    Str(&'a str),
    Missing,
}

/// Row-major accumulator that builds typed columns directly (no
/// intermediate tagged-cell buffer): numeric cells stream into the `f64`
/// lane, strings intern into the frame-local id space and stream into
/// the `u32` lane.
#[derive(Debug)]
pub struct RowFrameBuilder {
    n_features: usize,
    columns: Vec<ColumnShard>,
    interner: Interner,
    n_rows: usize,
}

impl RowFrameBuilder {
    pub fn new(n_features: usize) -> RowFrameBuilder {
        RowFrameBuilder {
            n_features,
            columns: (0..n_features).map(|_| ColumnShard::default()).collect(),
            interner: Interner::new(),
            n_rows: 0,
        }
    }

    /// Append one row. Errors on arity mismatch.
    pub fn push_row(&mut self, cells: &[Cell]) -> Result<()> {
        if cells.len() != self.n_features {
            return Err(UdtError::predict(format!(
                "expected {} features, got {}",
                self.n_features,
                cells.len()
            )));
        }
        let RowFrameBuilder {
            columns, interner, ..
        } = self;
        for (col, cell) in columns.iter_mut().zip(cells) {
            match cell {
                Cell::Num(x) => col.push_num(*x),
                Cell::Str(s) => col.push_cat(interner.intern(s).0),
                Cell::Missing => col.push_missing(),
            }
        }
        self.n_rows += 1;
        Ok(())
    }

    /// Specialize the accumulated lanes into typed columns.
    pub fn finish(self) -> RowFrame {
        RowFrame {
            n_rows: self.n_rows,
            columns: self.columns.into_iter().map(ColumnShard::finish).collect(),
            interner: Arc::new(self.interner),
        }
    }
}

/// A columnar batch of prediction inputs: typed per-feature columns and
/// a frame-local string interner for categorical cells. Build once,
/// predict many (see [`super::CompiledModel::predict_frame`]).
#[derive(Debug, Clone)]
pub struct RowFrame {
    n_rows: usize,
    columns: Vec<ColumnData>,
    interner: Arc<Interner>,
}

impl RowFrame {
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_features(&self) -> usize {
        self.columns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    /// The typed column of feature `f`.
    #[inline]
    pub fn column(&self, f: usize) -> &FrameColumn {
        &self.columns[f]
    }

    /// The frame-local interner (category id `i` ↔ `interner.names()[i]`).
    pub fn interner(&self) -> &Interner {
        &self.interner
    }

    /// Cell `(feature, row)` as a frame-local [`Value`] (tests/debug).
    pub fn cell(&self, f: usize, row: usize) -> Value {
        self.columns[f].get(row)
    }

    /// Columnar view of a dataset's feature matrix (labels are not
    /// carried — pair with `ds.labels` for evaluation). **Zero copy**:
    /// the frame shares the dataset's `Arc` column lanes and interner,
    /// so frame-local category ids are the dataset's ids.
    pub fn from_dataset(ds: &Dataset) -> RowFrame {
        RowFrame {
            n_rows: ds.n_rows(),
            columns: ds.columns.iter().map(|c| c.data.clone()).collect(),
            interner: Arc::clone(&ds.interner),
        }
    }

    /// Build from parsed JSON rows (each row a slice of cells: numbers,
    /// strings, or `null` for missing). Arity is taken from the first
    /// row; later rows must match.
    pub fn from_json_rows(rows: &[&[Json]]) -> Result<RowFrame> {
        let n_features = rows
            .first()
            .map(|r| r.len())
            .ok_or_else(|| UdtError::predict("empty row batch"))?;
        let mut b = RowFrameBuilder::new(n_features);
        for row in rows {
            let cells: Vec<Cell> = row.iter().map(json_cell).collect::<Result<_>>()?;
            b.push_row(&cells)?;
        }
        Ok(b.finish())
    }

    /// Build from JSON-lines text: one JSON array of cells per line
    /// (blank lines skipped).
    pub fn from_json_lines(text: &str) -> Result<RowFrame> {
        let mut docs = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parsed = Json::parse(line)
                .map_err(|e| UdtError::predict(format!("json line {}: {e}", i + 1)))?;
            docs.push(parsed);
        }
        let rows: Vec<&[Json]> = docs
            .iter()
            .map(|d| {
                d.as_arr()
                    .ok_or_else(|| UdtError::predict("each json line must be an array of cells"))
            })
            .collect::<Result<_>>()?;
        Self::from_json_rows(&rows)
    }

    /// Build from CSV text where **every** column is a feature (serving
    /// input carries no label column). Routed through the streaming
    /// parser in `data/csv.rs` — quoting/CRLF semantics and the hybrid
    /// numeric-first cell rule cannot drift from the training path.
    pub fn from_csv_str(text: &str, has_header: bool, delimiter: char) -> Result<RowFrame> {
        let opts = crate::data::csv::CsvOptions {
            has_header,
            delimiter,
            ..Default::default()
        };
        let parsed = crate::data::csv::parse_typed_csv("input", text, &opts, false)
            .map_err(|e| match e {
                UdtError::Data(msg) => UdtError::predict(msg),
                other => other,
            })?;
        Ok(RowFrame {
            n_rows: parsed.n_rows,
            columns: parsed.columns,
            interner: Arc::new(parsed.interner),
        })
    }

    /// Materialize row `r` as frame-local values (tests / slow paths).
    pub fn row(&self, r: usize) -> Vec<Value> {
        self.columns.iter().map(|c| c.get(r)).collect()
    }
}

/// Parse one JSON value into a builder cell — the single cell
/// classification rule shared by the frame path and the server's
/// single-row fast path (numbers, strings, `null` → missing; anything
/// else is a typed error).
pub(crate) fn json_cell(j: &Json) -> Result<Cell<'_>> {
    Ok(match j {
        Json::Null => Cell::Missing,
        Json::Num(x) => Cell::Num(*x),
        Json::Str(s) => Cell::Str(s),
        other => return Err(UdtError::predict(format!("bad cell {other:?}"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_classification, SynthSpec};

    #[test]
    fn builder_specializes_column_kinds() {
        let mut b = RowFrameBuilder::new(3);
        b.push_row(&[Cell::Num(1.0), Cell::Str("a"), Cell::Num(5.0)]).unwrap();
        b.push_row(&[Cell::Missing, Cell::Str("b"), Cell::Str("x")]).unwrap();
        b.push_row(&[Cell::Num(2.0), Cell::Missing, Cell::Num(7.0)]).unwrap();
        let f = b.finish();
        assert_eq!(f.n_rows(), 3);
        assert!(matches!(f.column(0), FrameColumn::Num { .. }));
        assert!(matches!(f.column(1), FrameColumn::Cat { .. }));
        assert!(matches!(f.column(2), FrameColumn::Hybrid { .. }));
        // Cells read back with missing preserved.
        assert_eq!(f.cell(0, 0), Value::Num(1.0));
        assert!(f.cell(0, 1).is_missing());
        assert!(f.cell(1, 2).is_missing());
        assert_eq!(
            f.interner().name(f.cell(1, 1).as_cat().unwrap()),
            "b"
        );
    }

    #[test]
    fn builder_rejects_bad_arity() {
        let mut b = RowFrameBuilder::new(2);
        assert!(b.push_row(&[Cell::Num(1.0)]).is_err());
    }

    #[test]
    fn from_dataset_preserves_cells() {
        let mut spec = SynthSpec::classification("fr", 300, 5, 2);
        spec.cat_frac = 0.4;
        spec.hybrid_frac = 0.2;
        spec.missing_frac = 0.1;
        let ds = generate_classification(&spec, 33);
        let f = RowFrame::from_dataset(&ds);
        assert_eq!(f.n_rows(), ds.n_rows());
        assert_eq!(f.n_features(), ds.n_features());
        for r in (0..ds.n_rows()).step_by(17) {
            for c in 0..ds.n_features() {
                match (ds.value(c, r), f.cell(c, r)) {
                    (Value::Num(a), Value::Num(b)) => assert_eq!(a, b),
                    (Value::Cat(a), Value::Cat(b)) => {
                        assert_eq!(ds.interner.name(a), f.interner().name(b))
                    }
                    (Value::Missing, Value::Missing) => {}
                    (a, b) => panic!("cell ({c},{r}): {a:?} vs {b:?}"),
                }
            }
        }
    }

    #[test]
    fn from_dataset_shares_storage() {
        let mut spec = SynthSpec::classification("share", 200, 4, 2);
        spec.cat_frac = 0.3;
        spec.hybrid_frac = 0.2;
        let ds = generate_classification(&spec, 7);
        let f = RowFrame::from_dataset(&ds);
        // The interner is the dataset's Arc, not a re-interned copy.
        assert!(Arc::ptr_eq(&ds.interner, &f.interner));
        // Column lanes are Arc-shared, byte for byte.
        for (c, col) in ds.columns.iter().enumerate() {
            match (&col.data, f.column(c)) {
                (
                    ColumnData::Num { vals: a, .. },
                    ColumnData::Num { vals: b, .. },
                ) => assert!(Arc::ptr_eq(a, b), "col {c} num lane copied"),
                (
                    ColumnData::Cat { ids: a, .. },
                    ColumnData::Cat { ids: b, .. },
                ) => assert!(Arc::ptr_eq(a, b), "col {c} cat lane copied"),
                (
                    ColumnData::Hybrid { vals: a, ids: ai, .. },
                    ColumnData::Hybrid { vals: b, ids: bi, .. },
                ) => {
                    assert!(Arc::ptr_eq(a, b), "col {c} num lane copied");
                    assert!(Arc::ptr_eq(ai, bi), "col {c} cat lane copied");
                }
                _ => panic!("col {c}: representation changed across the view"),
            }
        }
    }

    #[test]
    fn from_json_rows_and_lines_agree() {
        let lines = "[1.5, \"red\", null]\n[2.0, \"blue\", 7]\n";
        let f = RowFrame::from_json_lines(lines).unwrap();
        assert_eq!(f.n_rows(), 2);
        assert_eq!(f.n_features(), 3);
        assert_eq!(f.cell(0, 1), Value::Num(2.0));
        assert!(f.cell(2, 0).is_missing());
        // Ragged rows are typed errors.
        assert!(RowFrame::from_json_lines("[1,2]\n[1]\n").is_err());
        // Non-cell values are typed errors.
        assert!(RowFrame::from_json_lines("[true]\n").is_err());
    }

    #[test]
    fn from_csv_parses_hybrid_cells() {
        let f = RowFrame::from_csv_str("a,b\n1.5,red\n?,blue\n2,\n", true, ',').unwrap();
        assert_eq!(f.n_rows(), 3);
        assert_eq!(f.cell(0, 0), Value::Num(1.5));
        assert!(f.cell(0, 1).is_missing());
        assert!(f.cell(1, 0).is_cat());
        assert!(f.cell(1, 2).is_missing());
        assert!(RowFrame::from_csv_str("", false, ',').is_err());
        // Errors surface as Predict, matching the serving contract.
        assert!(matches!(
            RowFrame::from_csv_str("", false, ','),
            Err(UdtError::Predict(_))
        ));
    }
}
