//! Columnar prediction input: the [`RowFrame`].
//!
//! Serving parses request batches once into a frame — typed per-feature
//! columns plus a validity mask — and every model then predicts over the
//! same columnar view. Columns specialize on content:
//!
//! * [`FrameColumn::Num`] — contiguous `f64` payloads + validity bits;
//! * [`FrameColumn::Cat`] — contiguous frame-local category ids + bits;
//! * [`FrameColumn::Mixed`] — hybrid columns fall back to tagged cells.
//!
//! Categorical cells intern into a **frame-local** id space (the frame
//! never sees a model's interner); a [`super::CompiledModel`] translates
//! frame ids into its own baked operand space once per `predict_frame`
//! call, so the traversal inner loop is pure integer compares.
//!
//! Frames build once from rows ([`RowFrameBuilder`]), JSON arrays
//! ([`RowFrame::from_json_rows`] / [`RowFrame::from_json_lines`]), CSV
//! text ([`RowFrame::from_csv_str`]) or a [`Dataset`] view
//! ([`RowFrame::from_dataset`]).

use crate::data::dataset::Dataset;
use crate::data::interner::{CatId, Interner};
use crate::data::value::{parse_cell, Value};
use crate::error::{Result, UdtError};
use crate::util::json::Json;

/// Bit-per-row validity mask: a set bit means the cell is present, a
/// clear bit means missing.
#[derive(Debug, Clone)]
pub struct ValidityMask {
    bits: Box<[u64]>,
    len: usize,
}

impl ValidityMask {
    /// Build from per-row validity flags.
    pub fn from_flags(flags: &[bool]) -> ValidityMask {
        let mut bits = vec![0u64; flags.len().div_ceil(64)];
        for (i, &v) in flags.iter().enumerate() {
            if v {
                bits[i >> 6] |= 1u64 << (i & 63);
            }
        }
        ValidityMask {
            bits: bits.into_boxed_slice(),
            len: flags.len(),
        }
    }

    /// Whether row `i` holds a value (false = missing).
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.bits[i >> 6] >> (i & 63)) & 1 == 1
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of valid (present) rows.
    pub fn count_valid(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }
}

/// One typed feature column of a [`RowFrame`].
///
/// `Cat` ids (and `Value::Cat` payloads inside `Mixed` cells) live in the
/// frame's local interner space, not any model's.
#[derive(Debug, Clone)]
pub enum FrameColumn {
    /// All present cells numeric: values + validity (missing rows hold 0.0).
    Num { values: Box<[f64]>, valid: ValidityMask },
    /// All present cells categorical: frame-local ids + validity
    /// (missing rows hold id 0).
    Cat { ids: Box<[u32]>, valid: ValidityMask },
    /// Hybrid column (numeric and categorical cells mixed): tagged cells.
    Mixed { cells: Box<[Value]> },
}

impl FrameColumn {
    /// The cell at `row` as a frame-local [`Value`].
    #[inline]
    pub fn cell(&self, row: usize) -> Value {
        match self {
            FrameColumn::Num { values, valid } => {
                if valid.get(row) {
                    Value::Num(values[row])
                } else {
                    Value::Missing
                }
            }
            FrameColumn::Cat { ids, valid } => {
                if valid.get(row) {
                    Value::Cat(CatId(ids[row]))
                } else {
                    Value::Missing
                }
            }
            FrameColumn::Mixed { cells } => cells[row],
        }
    }
}

/// One raw input cell handed to the [`RowFrameBuilder`].
#[derive(Debug, Clone, Copy)]
pub enum Cell<'a> {
    Num(f64),
    Str(&'a str),
    Missing,
}

/// Row-major accumulator that specializes into a columnar [`RowFrame`].
#[derive(Debug)]
pub struct RowFrameBuilder {
    n_features: usize,
    columns: Vec<Vec<Value>>,
    interner: Interner,
    n_rows: usize,
}

impl RowFrameBuilder {
    pub fn new(n_features: usize) -> RowFrameBuilder {
        RowFrameBuilder {
            n_features,
            columns: (0..n_features).map(|_| Vec::new()).collect(),
            interner: Interner::new(),
            n_rows: 0,
        }
    }

    /// Append one row. Errors on arity mismatch.
    pub fn push_row(&mut self, cells: &[Cell]) -> Result<()> {
        if cells.len() != self.n_features {
            return Err(UdtError::predict(format!(
                "expected {} features, got {}",
                self.n_features,
                cells.len()
            )));
        }
        for (col, cell) in self.columns.iter_mut().zip(cells) {
            col.push(match cell {
                Cell::Num(x) => Value::Num(*x),
                Cell::Str(s) => Value::Cat(self.interner.intern(s)),
                Cell::Missing => Value::Missing,
            });
        }
        self.n_rows += 1;
        Ok(())
    }

    /// Specialize the accumulated cells into typed columns.
    pub fn finish(self) -> RowFrame {
        let columns = self.columns.into_iter().map(specialize).collect();
        RowFrame {
            n_rows: self.n_rows,
            columns,
            interner: self.interner,
        }
    }
}

/// Pick the densest representation a column's content allows.
fn specialize(cells: Vec<Value>) -> FrameColumn {
    let any_num = cells.iter().any(Value::is_num);
    let any_cat = cells.iter().any(Value::is_cat);
    if any_num && any_cat {
        return FrameColumn::Mixed {
            cells: cells.into_boxed_slice(),
        };
    }
    if any_cat {
        let flags: Vec<bool> = cells.iter().map(|v| !v.is_missing()).collect();
        let ids: Vec<u32> = cells
            .iter()
            .map(|v| v.as_cat().map(|c| c.0).unwrap_or(0))
            .collect();
        return FrameColumn::Cat {
            ids: ids.into_boxed_slice(),
            valid: ValidityMask::from_flags(&flags),
        };
    }
    // All-numeric (or all-missing, which the Num layout represents fine).
    let flags: Vec<bool> = cells.iter().map(|v| !v.is_missing()).collect();
    let values: Vec<f64> = cells
        .iter()
        .map(|v| v.as_num().unwrap_or(0.0))
        .collect();
    FrameColumn::Num {
        values: values.into_boxed_slice(),
        valid: ValidityMask::from_flags(&flags),
    }
}

/// A columnar batch of prediction inputs: typed per-feature columns, a
/// validity mask per column, and a frame-local string interner for
/// categorical cells. Build once, predict many (see
/// [`super::CompiledModel::predict_frame`]).
#[derive(Debug, Clone)]
pub struct RowFrame {
    n_rows: usize,
    columns: Vec<FrameColumn>,
    interner: Interner,
}

impl RowFrame {
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_features(&self) -> usize {
        self.columns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    /// The typed column of feature `f`.
    #[inline]
    pub fn column(&self, f: usize) -> &FrameColumn {
        &self.columns[f]
    }

    /// The frame-local interner (category id `i` ↔ `interner.names()[i]`).
    pub fn interner(&self) -> &Interner {
        &self.interner
    }

    /// Cell `(feature, row)` as a frame-local [`Value`] (tests/debug).
    pub fn cell(&self, f: usize, row: usize) -> Value {
        self.columns[f].cell(row)
    }

    /// Columnar view of a dataset's feature matrix (labels are not
    /// carried — pair with `ds.labels` for evaluation). Categorical
    /// cells translate into the frame's local id space through a dense
    /// id→id table built once from the dataset's interner — one intern
    /// per distinct string, not one hash lookup per cell.
    pub fn from_dataset(ds: &Dataset) -> RowFrame {
        let mut interner = Interner::new();
        let id_map: Vec<CatId> = ds
            .interner
            .names()
            .iter()
            .map(|n| interner.intern(n))
            .collect();
        let columns = ds
            .columns
            .iter()
            .map(|c| {
                let cells: Vec<Value> = c
                    .values
                    .iter()
                    .map(|v| match v {
                        Value::Num(x) => Value::Num(*x),
                        Value::Cat(id) => Value::Cat(id_map[id.0 as usize]),
                        Value::Missing => Value::Missing,
                    })
                    .collect();
                specialize(cells)
            })
            .collect();
        RowFrame {
            n_rows: ds.n_rows(),
            columns,
            interner,
        }
    }

    /// Build from parsed JSON rows (each row a slice of cells: numbers,
    /// strings, or `null` for missing). Arity is taken from the first
    /// row; later rows must match.
    pub fn from_json_rows(rows: &[&[Json]]) -> Result<RowFrame> {
        let n_features = rows
            .first()
            .map(|r| r.len())
            .ok_or_else(|| UdtError::predict("empty row batch"))?;
        let mut b = RowFrameBuilder::new(n_features);
        for row in rows {
            let cells: Vec<Cell> = row.iter().map(json_cell).collect::<Result<_>>()?;
            b.push_row(&cells)?;
        }
        Ok(b.finish())
    }

    /// Build from JSON-lines text: one JSON array of cells per line
    /// (blank lines skipped).
    pub fn from_json_lines(text: &str) -> Result<RowFrame> {
        let mut docs = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parsed = Json::parse(line)
                .map_err(|e| UdtError::predict(format!("json line {}: {e}", i + 1)))?;
            docs.push(parsed);
        }
        let rows: Vec<&[Json]> = docs
            .iter()
            .map(|d| {
                d.as_arr()
                    .ok_or_else(|| UdtError::predict("each json line must be an array of cells"))
            })
            .collect::<Result<_>>()?;
        Self::from_json_rows(&rows)
    }

    /// Build from CSV text where **every** column is a feature (serving
    /// input carries no label column). Cells parse numeric-first, fall
    /// back to categorical; empty / `?` / `NA` are missing.
    pub fn from_csv_str(text: &str, has_header: bool, delimiter: char) -> Result<RowFrame> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        if has_header {
            lines.next();
        }
        let mut b: Option<RowFrameBuilder> = None;
        for (i, line) in lines.enumerate() {
            let fields = crate::data::csv::parse_record(line, delimiter);
            let builder = b.get_or_insert_with(|| RowFrameBuilder::new(fields.len()));
            // Classify through the shared hybrid rule (the placeholder id
            // is discarded — push_row interns into the frame's space).
            let cells: Vec<Cell> = fields
                .iter()
                .map(|raw| match parse_cell(raw, |_| CatId(0)) {
                    Value::Num(x) => Cell::Num(x),
                    Value::Missing => Cell::Missing,
                    Value::Cat(_) => Cell::Str(raw.trim()),
                })
                .collect();
            builder.push_row(&cells).map_err(|_| {
                UdtError::predict(format!(
                    "csv row {} has {} fields, expected {}",
                    i + 1,
                    fields.len(),
                    builder.n_features
                ))
            })?;
        }
        match b {
            Some(builder) => Ok(builder.finish()),
            None => Err(UdtError::predict("csv input has no data rows")),
        }
    }

    /// Materialize row `r` as frame-local values (tests / slow paths).
    pub fn row(&self, r: usize) -> Vec<Value> {
        self.columns.iter().map(|c| c.cell(r)).collect()
    }
}

/// Parse one JSON value into a builder cell — the single cell
/// classification rule shared by the frame path and the server's
/// single-row fast path (numbers, strings, `null` → missing; anything
/// else is a typed error).
pub(crate) fn json_cell(j: &Json) -> Result<Cell<'_>> {
    Ok(match j {
        Json::Null => Cell::Missing,
        Json::Num(x) => Cell::Num(*x),
        Json::Str(s) => Cell::Str(s),
        other => return Err(UdtError::predict(format!("bad cell {other:?}"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_classification, SynthSpec};

    #[test]
    fn validity_mask_round_trips() {
        let flags: Vec<bool> = (0..130).map(|i| i % 3 != 0).collect();
        let m = ValidityMask::from_flags(&flags);
        assert_eq!(m.len(), 130);
        for (i, &f) in flags.iter().enumerate() {
            assert_eq!(m.get(i), f, "bit {i}");
        }
        assert_eq!(m.count_valid(), flags.iter().filter(|&&f| f).count());
    }

    #[test]
    fn builder_specializes_column_kinds() {
        let mut b = RowFrameBuilder::new(3);
        b.push_row(&[Cell::Num(1.0), Cell::Str("a"), Cell::Num(5.0)]).unwrap();
        b.push_row(&[Cell::Missing, Cell::Str("b"), Cell::Str("x")]).unwrap();
        b.push_row(&[Cell::Num(2.0), Cell::Missing, Cell::Num(7.0)]).unwrap();
        let f = b.finish();
        assert_eq!(f.n_rows(), 3);
        assert!(matches!(f.column(0), FrameColumn::Num { .. }));
        assert!(matches!(f.column(1), FrameColumn::Cat { .. }));
        assert!(matches!(f.column(2), FrameColumn::Mixed { .. }));
        // Cells read back with missing preserved.
        assert_eq!(f.cell(0, 0), Value::Num(1.0));
        assert!(f.cell(0, 1).is_missing());
        assert!(f.cell(1, 2).is_missing());
        assert_eq!(
            f.interner().name(f.cell(1, 1).as_cat().unwrap()),
            "b"
        );
    }

    #[test]
    fn builder_rejects_bad_arity() {
        let mut b = RowFrameBuilder::new(2);
        assert!(b.push_row(&[Cell::Num(1.0)]).is_err());
    }

    #[test]
    fn from_dataset_preserves_cells() {
        let mut spec = SynthSpec::classification("fr", 300, 5, 2);
        spec.cat_frac = 0.4;
        spec.hybrid_frac = 0.2;
        spec.missing_frac = 0.1;
        let ds = generate_classification(&spec, 33);
        let f = RowFrame::from_dataset(&ds);
        assert_eq!(f.n_rows(), ds.n_rows());
        assert_eq!(f.n_features(), ds.n_features());
        for r in (0..ds.n_rows()).step_by(17) {
            for c in 0..ds.n_features() {
                match (ds.value(c, r), f.cell(c, r)) {
                    (Value::Num(a), Value::Num(b)) => assert_eq!(a, b),
                    (Value::Cat(a), Value::Cat(b)) => {
                        assert_eq!(ds.interner.name(a), f.interner().name(b))
                    }
                    (Value::Missing, Value::Missing) => {}
                    (a, b) => panic!("cell ({c},{r}): {a:?} vs {b:?}"),
                }
            }
        }
    }

    #[test]
    fn from_json_rows_and_lines_agree() {
        let lines = "[1.5, \"red\", null]\n[2.0, \"blue\", 7]\n";
        let f = RowFrame::from_json_lines(lines).unwrap();
        assert_eq!(f.n_rows(), 2);
        assert_eq!(f.n_features(), 3);
        assert_eq!(f.cell(0, 1), Value::Num(2.0));
        assert!(f.cell(2, 0).is_missing());
        // Ragged rows are typed errors.
        assert!(RowFrame::from_json_lines("[1,2]\n[1]\n").is_err());
        // Non-cell values are typed errors.
        assert!(RowFrame::from_json_lines("[true]\n").is_err());
    }

    #[test]
    fn from_csv_parses_hybrid_cells() {
        let f = RowFrame::from_csv_str("a,b\n1.5,red\n?,blue\n2,\n", true, ',').unwrap();
        assert_eq!(f.n_rows(), 3);
        assert_eq!(f.cell(0, 0), Value::Num(1.5));
        assert!(f.cell(0, 1).is_missing());
        assert!(f.cell(1, 0).is_cat());
        assert!(f.cell(1, 2).is_missing());
        assert!(RowFrame::from_csv_str("", false, ',').is_err());
    }
}
