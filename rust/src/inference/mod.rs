//! The compiled inference subsystem: compile-once / predict-many.
//!
//! Training produces boxed [`crate::tree::Node`] arenas — flexible to
//! grow, slow to traverse at serving volume (an `Option<SplitPredicate>`
//! + `Option<(u32, u32)>` unwrap and a 16-byte tagged [`Value`] read per
//! step, one `Vec<Value>` allocation per predicted row, and a model
//! family re-match per request). This module is the other half of the
//! system: a serving-shaped data path.
//!
//! * [`CompiledModel`] — `Model::compile()` flattens every tree into
//!   struct-of-arrays node tables (tag / feature / operand / pos / neg /
//!   label, contiguous `Box<[_]>`s, positive child adjacent to its
//!   parent) and bakes both the Training-Only-Once tuned caps and the
//!   categorical interner (as per-feature string → operand lookups) into
//!   the artifact. Traversal is a handful of sequential integer loads
//!   per step; see [`compiled`] for the exact layout.
//! * [`RowFrame`] — columnar prediction input: a thin view over the same
//!   typed [`crate::data::column_data::ColumnData`] store training uses
//!   (dense `f64`/`u32` lanes + kind masks), built once from rows, CSV,
//!   JSON lines — or **shared zero-copy** from a [`crate::Dataset`].
//! * [`Predictions`] — rich output of
//!   [`CompiledModel::predict_frame`]: labels plus, for classification
//!   forests, per-class [`VoteCounts`] and vote margins.
//!
//! ```no_run
//! use udt::data::synth::{generate_classification, SynthSpec};
//! use udt::inference::RowFrame;
//! use udt::{Model, SavedModel, Udt};
//!
//! # fn main() -> udt::Result<()> {
//! let ds = generate_classification(&SynthSpec::classification("d", 10_000, 8, 3), 42);
//! let saved = SavedModel::new(Model::SingleTree(Udt::builder().fit(&ds)?), &ds);
//! let compiled = saved.compile()?;          // flatten once
//! let frame = RowFrame::from_dataset(&ds);  // parse inputs once
//! let preds = compiled.predict_frame(&frame)?; // predict many, in parallel
//! println!("{} predictions", preds.len());
//! # Ok(())
//! # }
//! ```

pub mod compiled;
pub mod frame;

pub use compiled::{CompiledModel, Predictions, VoteCounts};
pub use frame::{Cell, FrameColumn, RowFrame, RowFrameBuilder, ValidityMask};
