//! The compiled prediction artifact: [`CompiledModel`].
//!
//! `Model::compile()` flattens every tree of any family into
//! struct-of-arrays node tables — per node a feature id, a split operand
//! tag + payload, positive/negative child indices, and the node label —
//! each table a contiguous `Box<[_]>` with the root at index 0 and the
//! positive child laid out adjacent to its parent (pre-order), so a
//! traversal is a handful of sequential array reads with **zero** boxed
//! pointer chasing or `Option` unwrapping per step.
//!
//! Two more things bake in at compile time:
//!
//! * **Tuned caps.** A `Model::TunedTree`'s effective
//!   `(max_depth, min_split)` are applied structurally: any node the
//!   capped walk would answer from becomes a leaf in the compiled table,
//!   so the hot loop carries no depth counter and no per-step cap
//!   comparisons (paper Algorithm 7 semantics, paid once at compile).
//! * **The interner.** Each feature gets its own categorical lookup
//!   mapping the category *strings* its `Eq` splits test to their operand
//!   ids. Resolving a request's string cells never touches the global
//!   training interner: [`CompiledModel::predict_frame`] translates the
//!   frame's local id space through the per-feature lookups once per
//!   frame, and the inner loop compares integers.
//!
//! Node table layout (one `CompiledTree` per member tree):
//!
//! ```text
//! index:    0        1        2        3     ...   (root = 0, pos child adjacent)
//! tag:     [Le]     [Eq]     [Leaf]   [Leaf] ...   u8: Leaf / Le / Gt / Eq
//! feature: [3]      [0]      [-]      [-]    ...   u32 feature id
//! operand: [f64bits][cat id] [-]      [-]    ...   u64 payload (threshold bits / cat id)
//! pos:     [1]      [2]      [-]      [-]    ...   u32 child index (predicate true)
//! neg:     [9]      [3]      [-]      [-]    ...   u32 child index (false / missing)
//! label:   [...]    [...]    [c1]     [c0]   ...   u16 class or f64 value
//! ```
//!
//! Prediction over a [`RowFrame`] is block-iterated: rows are split into
//! fixed-size chunks, chunks fan out over [`parallel_map_chunked`], and
//! within a chunk the row loop is tight over the tables. Forest chunks aggregate
//! member votes per row in tree order (bit-identical to the boxed
//! ensemble path) and return per-class vote counts in [`Predictions`];
//! boosted chunks accumulate per-channel leaf sums in the same storage
//! order as the boxed path and score them through the one shared
//! [`crate::tree::boost::decide_scores`] rule (sum of leaf values +
//! sigmoid/argmax), so boosted predictions are bit-identical too.

use super::frame::{FrameColumn, RowFrame};
use crate::coordinator::parallel::parallel_map_chunked;
use crate::data::column_data::{present, ColumnData};
use crate::data::dataset::{Labels, TaskKind};
use crate::data::interner::Interner;
use crate::data::value::Value;
use crate::error::{Result, UdtError};
use crate::model::{Model, Quality};
use crate::selection::split::SplitOp;
use crate::tree::forest::vote_argmax;
use crate::tree::{NodeLabel, Tree};
use std::collections::HashMap;

/// Node tags of the flattened tables.
const TAG_LEAF: u8 = 0;
const TAG_LE: u8 = 1;
const TAG_GT: u8 = 2;
const TAG_EQ: u8 = 3;

/// Sentinel for "this frame category can never match any operand".
const NO_MATCH: u32 = u32::MAX;

/// Rows per traversal block (chunks parallelize over the worker pool).
const CHUNK_ROWS: usize = 512;

/// Leaf payloads of one compiled tree (one task kind per model).
#[derive(Debug, Clone)]
enum CompiledLabels {
    Class(Box<[u16]>),
    Value(Box<[f64]>),
}

/// One flattened tree: parallel struct-of-arrays node tables.
#[derive(Debug, Clone)]
struct CompiledTree {
    tag: Box<[u8]>,
    feature: Box<[u32]>,
    operand: Box<[u64]>,
    pos: Box<[u32]>,
    neg: Box<[u32]>,
    labels: CompiledLabels,
}

impl CompiledTree {
    /// Flatten a boxed tree, baking prediction-time caps structurally:
    /// nodes the capped walk answers from become leaves. Pre-order with
    /// the positive child first keeps the common branch adjacent.
    fn flatten(tree: &Tree, max_depth: usize, min_split: usize) -> CompiledTree {
        let mut tag: Vec<u8> = Vec::with_capacity(tree.n_nodes());
        let mut feature: Vec<u32> = Vec::with_capacity(tree.n_nodes());
        let mut operand: Vec<u64> = Vec::with_capacity(tree.n_nodes());
        let mut pos: Vec<u32> = Vec::with_capacity(tree.n_nodes());
        let mut neg: Vec<u32> = Vec::with_capacity(tree.n_nodes());
        let mut class_labels: Vec<u16> = Vec::new();
        let mut value_labels: Vec<f64> = Vec::new();
        let is_class = tree.task == TaskKind::Classification;

        // (source node, patch site in the parent's pos/neg cell).
        enum Patch {
            Root,
            Pos(usize),
            Neg(usize),
        }
        let mut stack: Vec<(u32, Patch)> = vec![(Tree::ROOT, Patch::Root)];
        while let Some((src, patch)) = stack.pop() {
            let node = &tree.nodes[src as usize];
            let slot = tag.len();
            match patch {
                Patch::Root => {}
                Patch::Pos(p) => pos[p] = slot as u32,
                Patch::Neg(p) => neg[p] = slot as u32,
            }
            match node.label {
                NodeLabel::Class(c) => class_labels.push(c),
                NodeLabel::Value(v) => value_labels.push(v),
            }
            // The boxed walk answers here when the node is a leaf OR the
            // tuned caps cut it off (walk depth equals the stored node
            // depth, root = 1) — bake that as a structural leaf.
            let capped = (node.n_samples as usize) < min_split
                || node.depth as usize >= max_depth;
            match (&node.split, node.children) {
                (Some(split), Some((p, n))) if !capped => {
                    let (t, op) = match split.op {
                        SplitOp::Le(x) => (TAG_LE, x.to_bits()),
                        SplitOp::Gt(x) => (TAG_GT, x.to_bits()),
                        SplitOp::Eq(c) => (TAG_EQ, c.0 as u64),
                    };
                    tag.push(t);
                    feature.push(split.feature as u32);
                    operand.push(op);
                    pos.push(0);
                    neg.push(0);
                    // Neg first so the positive child pops (and lays out)
                    // immediately after its parent.
                    stack.push((n, Patch::Neg(slot)));
                    stack.push((p, Patch::Pos(slot)));
                }
                _ => {
                    tag.push(TAG_LEAF);
                    feature.push(0);
                    operand.push(0);
                    pos.push(0);
                    neg.push(0);
                }
            }
        }

        CompiledTree {
            tag: tag.into_boxed_slice(),
            feature: feature.into_boxed_slice(),
            operand: operand.into_boxed_slice(),
            pos: pos.into_boxed_slice(),
            neg: neg.into_boxed_slice(),
            labels: if is_class {
                CompiledLabels::Class(class_labels.into_boxed_slice())
            } else {
                CompiledLabels::Value(value_labels.into_boxed_slice())
            },
        }
    }

    fn n_nodes(&self) -> usize {
        self.tag.len()
    }

    /// Resident size of this tree's node tables, derived from the
    /// actual element types so it tracks layout changes.
    fn table_bytes(&self) -> usize {
        use std::mem::size_of;
        let labels = match &self.labels {
            CompiledLabels::Class(ls) => ls.len() * size_of::<u16>(),
            CompiledLabels::Value(ls) => ls.len() * size_of::<f64>(),
        };
        self.tag.len()
            * (size_of::<u8>() + size_of::<u32>() + size_of::<u64>() + 2 * size_of::<u32>())
            + labels
    }

    /// Walk one frame row to its leaf; returns the leaf's table index.
    /// `cat_maps[f]` translates frame-local category ids into this
    /// model's operand space (`NO_MATCH` for categories feature `f`
    /// never tests).
    #[inline]
    fn walk_frame(&self, frame: &RowFrame, row: usize, cat_maps: &[Vec<u32>]) -> usize {
        let mut i = 0usize;
        loop {
            let t = self.tag[i];
            if t == TAG_LEAF {
                return i;
            }
            let f = self.feature[i] as usize;
            let hit = eval_frame_cell(frame.column(f), row, t, self.operand[i], &cat_maps[f]);
            i = if hit { self.pos[i] } else { self.neg[i] } as usize;
        }
    }

    /// Walk one row of model-space values (`Value::Cat` ids in the
    /// training interner's space).
    #[inline]
    fn walk_values(&self, row: &[Value]) -> usize {
        let mut i = 0usize;
        loop {
            let t = self.tag[i];
            if t == TAG_LEAF {
                return i;
            }
            let hit = eval_model_cell(row[self.feature[i] as usize], t, self.operand[i]);
            i = if hit { self.pos[i] } else { self.neg[i] } as usize;
        }
    }

    #[inline]
    fn class_at(&self, leaf: usize) -> u16 {
        match &self.labels {
            CompiledLabels::Class(ls) => ls[leaf],
            CompiledLabels::Value(_) => 0,
        }
    }

    #[inline]
    fn value_at(&self, leaf: usize) -> f64 {
        match &self.labels {
            CompiledLabels::Value(ls) => ls[leaf],
            CompiledLabels::Class(_) => f64::NAN,
        }
    }

    #[inline]
    fn label_at(&self, leaf: usize) -> NodeLabel {
        match &self.labels {
            CompiledLabels::Class(ls) => NodeLabel::Class(ls[leaf]),
            CompiledLabels::Value(ls) => NodeLabel::Value(ls[leaf]),
        }
    }
}

/// Evaluate one compiled predicate against a frame cell, straight off
/// the shared typed lanes (paper Table 3 semantics: cross-type and
/// missing always false → negative branch). No tagged `Value` is
/// constructed anywhere in the traversal.
#[inline]
fn eval_frame_cell(col: &FrameColumn, row: usize, tag: u8, operand: u64, cat_map: &[u32]) -> bool {
    match col {
        ColumnData::Num { vals, valid } => {
            if tag == TAG_EQ || !present(valid, row) {
                return false;
            }
            let x = vals[row];
            if tag == TAG_LE {
                x <= f64::from_bits(operand)
            } else {
                x > f64::from_bits(operand)
            }
        }
        ColumnData::Cat { ids, valid } => {
            tag == TAG_EQ
                && present(valid, row)
                && translate(cat_map, ids[row]) as u64 == operand
        }
        ColumnData::Hybrid {
            vals,
            ids,
            num,
            cat,
        } => match tag {
            TAG_LE if num.get(row) => vals[row] <= f64::from_bits(operand),
            TAG_GT if num.get(row) => vals[row] > f64::from_bits(operand),
            TAG_EQ if cat.get(row) => translate(cat_map, ids[row]) as u64 == operand,
            _ => false,
        },
    }
}

#[inline]
fn translate(cat_map: &[u32], frame_id: u32) -> u32 {
    cat_map.get(frame_id as usize).copied().unwrap_or(NO_MATCH)
}

/// Evaluate one compiled predicate against a model-space value.
#[inline]
fn eval_model_cell(v: Value, tag: u8, operand: u64) -> bool {
    match (tag, v) {
        (TAG_LE, Value::Num(x)) => x <= f64::from_bits(operand),
        (TAG_GT, Value::Num(x)) => x > f64::from_bits(operand),
        (TAG_EQ, Value::Cat(c)) => c.0 as u64 == operand,
        _ => false,
    }
}

/// How member predictions combine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Aggregation {
    /// One tree: the leaf label answers.
    Single,
    /// Classification ensemble: majority vote, ties toward the smaller
    /// class id (identical to `Forest::aggregate`).
    ForestVote,
    /// Regression ensemble: mean of member leaf values (tree order).
    ForestMean,
    /// Gradient-boosted ensemble: per-channel leaf sums scored through
    /// the shared [`crate::tree::boost::decide_scores`] rule (identical
    /// float operations to the boxed path — bit-identical predictions).
    Boosted,
}

/// Per-class vote counts of a classification forest, row-major.
#[derive(Debug, Clone)]
pub struct VoteCounts {
    n_classes: usize,
    n_trees: usize,
    counts: Vec<u32>,
}

impl VoteCounts {
    /// Votes for row `r`, one count per class id.
    pub fn row(&self, r: usize) -> &[u32] {
        &self.counts[r * self.n_classes..(r + 1) * self.n_classes]
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn n_trees(&self) -> usize {
        self.n_trees
    }

    /// Vote margin of row `r`: (winner − runner-up) / ensemble size, in
    /// `[0, 1]`. 1.0 for a unanimous ensemble (or a single class).
    pub fn margin(&self, r: usize) -> f64 {
        let votes = self.row(r);
        let mut top = 0u32;
        let mut second = 0u32;
        for &v in votes {
            if v > top {
                second = top;
                top = v;
            } else if v > second {
                second = v;
            }
        }
        (top - second) as f64 / self.n_trees.max(1) as f64
    }
}

/// Rich prediction output of [`CompiledModel::predict_frame`]: one label
/// per row, plus per-class vote counts when the model is a
/// classification forest.
#[derive(Debug, Clone)]
pub struct Predictions {
    labels: Vec<NodeLabel>,
    votes: Option<VoteCounts>,
}

impl Predictions {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn labels(&self) -> &[NodeLabel] {
        &self.labels
    }

    pub fn into_labels(self) -> Vec<NodeLabel> {
        self.labels
    }

    pub fn label(&self, r: usize) -> NodeLabel {
        self.labels[r]
    }

    /// Ensemble vote counts (classification forests only).
    pub fn votes(&self) -> Option<&VoteCounts> {
        self.votes.as_ref()
    }
}

/// A compile-once / predict-many artifact of any [`Model`] family. See
/// the module docs for the flattened layout. Cheap to share across
/// serving threads (`Sync`, no interior mutability).
#[derive(Debug, Clone)]
pub struct CompiledModel {
    kind: &'static str,
    task: TaskKind,
    n_features: usize,
    /// Classes of the label space (classification forests vote into it;
    /// 0 for regression and plain trees compiled without one known).
    n_classes: usize,
    agg: Aggregation,
    trees: Box<[CompiledTree]>,
    /// Per-feature baked categorical lookup: category string → the
    /// operand id this feature's `Eq` nodes test. Strings absent from a
    /// feature's table can never satisfy any of its splits.
    cat_lookup: Box<[HashMap<String, u32>]>,
    /// Boosted only: shrinkage applied to every leaf contribution.
    learning_rate: f64,
    /// Boosted only: initial score per channel (empty otherwise).
    base: Box<[f64]>,
    /// Boosted only: boosting rounds (0 otherwise).
    rounds: usize,
}

impl CompiledModel {
    /// Compile a model with the interner it was trained with (categorical
    /// operand ids resolve through it into the baked per-feature
    /// lookups). [`crate::model::SavedModel::compile`] passes the
    /// bundled interner.
    pub fn compile(model: &Model, interner: &Interner) -> Result<CompiledModel> {
        // Boosted-only scoring state; filled by the Boosted arm below.
        let mut learning_rate = 0.0f64;
        let mut base: Box<[f64]> = Box::default();
        let mut rounds = 0usize;
        let (trees, agg, n_classes): (Vec<CompiledTree>, Aggregation, usize) = match model {
            Model::SingleTree(t) => {
                (vec![CompiledTree::flatten(t, usize::MAX, 0)], Aggregation::Single, 0)
            }
            Model::TunedTree {
                tree,
                max_depth,
                min_split,
            } => (
                vec![CompiledTree::flatten(tree, *max_depth, *min_split)],
                Aggregation::Single,
                0,
            ),
            Model::Forest(f) => {
                let trees = f
                    .trees
                    .iter()
                    .map(|t| CompiledTree::flatten(t, usize::MAX, 0))
                    .collect();
                let agg = match f.task {
                    TaskKind::Classification => Aggregation::ForestVote,
                    TaskKind::Regression => Aggregation::ForestMean,
                };
                (trees, agg, f.n_classes)
            }
            Model::Boosted(b) => {
                let trees = b
                    .trees
                    .iter()
                    .map(|t| CompiledTree::flatten(t, usize::MAX, 0))
                    .collect();
                learning_rate = b.learning_rate;
                base = b.base.clone().into_boxed_slice();
                rounds = b.n_rounds();
                (trees, Aggregation::Boosted, b.n_classes)
            }
        };

        // Bake the interner: per feature, the strings its Eq operands
        // name. An operand id outside the interner is a corrupt model.
        let n_features = model.n_features();
        let mut cat_lookup: Vec<HashMap<String, u32>> = vec![HashMap::new(); n_features];
        for tree in &trees {
            for i in 0..tree.n_nodes() {
                if tree.tag[i] == TAG_EQ {
                    let id = tree.operand[i] as u32;
                    let name = interner.names().get(id as usize).ok_or_else(|| {
                        UdtError::model(format!(
                            "categorical operand {id} out of interner range ({})",
                            interner.len()
                        ))
                    })?;
                    cat_lookup[tree.feature[i] as usize].insert(name.clone(), id);
                }
            }
        }

        Ok(CompiledModel {
            kind: model.kind(),
            task: model.task(),
            n_features,
            n_classes,
            agg,
            trees: trees.into_boxed_slice(),
            cat_lookup: cat_lookup.into_boxed_slice(),
            learning_rate,
            base,
            rounds,
        })
    }

    /// Family tag of the source model (`single_tree` / `tuned_tree` /
    /// `forest` / `boosted`).
    pub fn kind(&self) -> &'static str {
        self.kind
    }

    pub fn task(&self) -> TaskKind {
        self.task
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Boosting rounds of a boosted model (0 for every other family) —
    /// surfaced in the server's per-model `stats`.
    pub fn n_rounds(&self) -> usize {
        self.rounds
    }

    /// Score channels of a boosted model (1 for regression/binary,
    /// `n_classes` for one-vs-rest).
    fn boost_group(&self) -> usize {
        crate::tree::boost::group_of(self.task, self.n_classes).max(1)
    }

    /// Total flattened node count across member trees.
    pub fn n_nodes(&self) -> usize {
        self.trees.iter().map(CompiledTree::n_nodes).sum()
    }

    /// Resident size of the flattened node tables, in bytes (reported
    /// per model in the server's `stats`).
    pub fn table_bytes(&self) -> usize {
        self.trees.iter().map(CompiledTree::table_bytes).sum()
    }

    /// Translate the frame's local category ids into this model's
    /// operand space, once per feature: `maps[f][frame_id]` is the
    /// operand id feature `f` knows the string as, or `NO_MATCH`.
    fn build_cat_maps(&self, frame: &RowFrame) -> Vec<Vec<u32>> {
        let names = frame.interner().names();
        self.cat_lookup
            .iter()
            .map(|lookup| {
                if lookup.is_empty() {
                    return Vec::new();
                }
                names
                    .iter()
                    .map(|n| lookup.get(n).copied().unwrap_or(NO_MATCH))
                    .collect()
            })
            .collect()
    }

    /// Predict every row of a frame, chunk-parallel over all cores.
    pub fn predict_frame(&self, frame: &RowFrame) -> Result<Predictions> {
        self.predict_frame_threads(frame, 0)
    }

    /// [`predict_frame`](Self::predict_frame) with an explicit worker
    /// count (0 = all cores, 1 = sequential). Thread count never changes
    /// the predictions — chunks are independent and stitched in order.
    pub fn predict_frame_threads(&self, frame: &RowFrame, n_threads: usize) -> Result<Predictions> {
        if frame.n_features() != self.n_features {
            return Err(UdtError::predict(format!(
                "expected {} features, got {}",
                self.n_features,
                frame.n_features()
            )));
        }
        let n = frame.n_rows();
        let cat_maps = self.build_cat_maps(frame);
        let outs = parallel_map_chunked(n, CHUNK_ROWS, n_threads, |start, end| {
            self.predict_chunk(frame, start, end, &cat_maps)
        });

        let mut labels = Vec::with_capacity(n);
        let mut counts: Vec<u32> = Vec::new();
        for mut out in outs {
            labels.append(&mut out.labels);
            counts.append(&mut out.votes);
        }
        let votes = (self.agg == Aggregation::ForestVote).then(|| VoteCounts {
            n_classes: self.n_classes.max(1),
            n_trees: self.trees.len(),
            counts,
        });
        Ok(Predictions { labels, votes })
    }

    /// Predict rows `[start, end)` of the frame: tight block loop, member
    /// trees aggregated per row in tree order (bit-identical to the boxed
    /// ensemble path).
    fn predict_chunk(
        &self,
        frame: &RowFrame,
        start: usize,
        end: usize,
        cat_maps: &[Vec<u32>],
    ) -> ChunkOut {
        let n = end - start;
        match self.agg {
            Aggregation::Single => {
                let tree = &self.trees[0];
                let labels = (start..end)
                    .map(|r| tree.label_at(tree.walk_frame(frame, r, cat_maps)))
                    .collect();
                ChunkOut {
                    labels,
                    votes: Vec::new(),
                }
            }
            Aggregation::ForestVote => {
                let n_classes = self.n_classes.max(1);
                let mut votes = vec![0u32; n * n_classes];
                for tree in self.trees.iter() {
                    for (i, r) in (start..end).enumerate() {
                        let c = tree.class_at(tree.walk_frame(frame, r, cat_maps)) as usize;
                        if c < n_classes {
                            votes[i * n_classes + c] += 1;
                        }
                    }
                }
                let labels = (0..n)
                    .map(|i| {
                        let row = &votes[i * n_classes..(i + 1) * n_classes];
                        NodeLabel::Class(vote_argmax(row) as u16)
                    })
                    .collect();
                ChunkOut { labels, votes }
            }
            Aggregation::ForestMean => {
                let mut sums = vec![0.0f64; n];
                for tree in self.trees.iter() {
                    for (i, r) in (start..end).enumerate() {
                        sums[i] += tree.value_at(tree.walk_frame(frame, r, cat_maps));
                    }
                }
                let t = self.trees.len().max(1) as f64;
                ChunkOut {
                    labels: sums.into_iter().map(|s| NodeLabel::Value(s / t)).collect(),
                    votes: Vec::new(),
                }
            }
            Aggregation::Boosted => {
                // Per-channel leaf sums accumulated in storage order
                // (round-major, class-minor) — exactly the boxed path's
                // accumulation order, then the one shared scoring rule:
                // bit-identical predictions.
                let group = self.boost_group();
                let mut sums = vec![0.0f64; n * group];
                for (t, tree) in self.trees.iter().enumerate() {
                    let k = t % group;
                    for (i, r) in (start..end).enumerate() {
                        sums[i * group + k] += tree.value_at(tree.walk_frame(frame, r, cat_maps));
                    }
                }
                let labels = (0..n)
                    .map(|i| {
                        crate::tree::boost::decide_scores(
                            self.task,
                            &self.base,
                            self.learning_rate,
                            &sums[i * group..(i + 1) * group],
                        )
                    })
                    .collect();
                ChunkOut {
                    labels,
                    votes: Vec::new(),
                }
            }
        }
    }

    /// Predict one row of model-space values — the signature-compatible
    /// shim over the compiled tables (`Value::Cat` ids must be in the
    /// training interner's space, as with `Estimator::predict_row`).
    pub fn predict_row(&self, row: &[Value]) -> Result<NodeLabel> {
        if row.len() != self.n_features {
            return Err(UdtError::predict(format!(
                "expected {} features, got {}",
                self.n_features,
                row.len()
            )));
        }
        Ok(match self.agg {
            Aggregation::Single => {
                let tree = &self.trees[0];
                tree.label_at(tree.walk_values(row))
            }
            Aggregation::ForestVote => {
                let n_classes = self.n_classes.max(1);
                let mut votes = vec![0u32; n_classes];
                for tree in self.trees.iter() {
                    let c = tree.class_at(tree.walk_values(row)) as usize;
                    if c < n_classes {
                        votes[c] += 1;
                    }
                }
                NodeLabel::Class(vote_argmax(&votes) as u16)
            }
            Aggregation::ForestMean => {
                let sum: f64 = self
                    .trees
                    .iter()
                    .map(|t| t.value_at(t.walk_values(row)))
                    .sum();
                NodeLabel::Value(sum / self.trees.len().max(1) as f64)
            }
            Aggregation::Boosted => {
                let group = self.boost_group();
                let mut sums = vec![0.0f64; group];
                for (t, tree) in self.trees.iter().enumerate() {
                    sums[t % group] += tree.value_at(tree.walk_values(row));
                }
                crate::tree::boost::decide_scores(
                    self.task,
                    &self.base,
                    self.learning_rate,
                    &sums,
                )
            }
        })
    }

    /// Batch shim over [`predict_row`](Self::predict_row) (model-space
    /// values; prefer [`predict_frame`](Self::predict_frame) for volume).
    pub fn predict_batch(&self, rows: &[Vec<Value>]) -> Result<Vec<NodeLabel>> {
        rows.iter().map(|r| self.predict_row(r)).collect()
    }

    /// Predict a frame and score against labels (accuracy, or MAE/RMSE).
    pub fn evaluate_frame(&self, frame: &RowFrame, labels: &Labels) -> Result<Quality> {
        crate::tree::require_task(self.task, labels.kind())?;
        if frame.n_rows() != labels.len() {
            return Err(UdtError::predict(format!(
                "frame has {} rows but labels have {}",
                frame.n_rows(),
                labels.len()
            )));
        }
        let preds = self.predict_frame(frame)?;
        match labels {
            Labels::Class { ids, .. } => {
                let correct = preds
                    .labels()
                    .iter()
                    .zip(ids)
                    .filter(|(p, &y)| p.as_class() == Some(y))
                    .count();
                Ok(Quality::Accuracy(correct as f64 / ids.len().max(1) as f64))
            }
            Labels::Reg { values } => {
                let (mae, rmse) = crate::tree::mae_rmse(
                    preds
                        .labels()
                        .iter()
                        .zip(values)
                        .map(|(p, &y)| (p.as_value().unwrap_or(f64::NAN), y)),
                );
                Ok(Quality::Regression { mae, rmse })
            }
        }
    }
}

/// Per-chunk traversal output (votes empty unless `ForestVote`).
struct ChunkOut {
    labels: Vec<NodeLabel>,
    votes: Vec<u32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_any, generate_classification, SynthSpec};
    use crate::model::Udt;
    use crate::tree::forest::{Forest, ForestConfig};

    fn hybrid_ds() -> crate::data::dataset::Dataset {
        let mut spec = SynthSpec::classification("cmp", 800, 6, 3);
        spec.cat_frac = 0.35;
        spec.hybrid_frac = 0.15;
        spec.missing_frac = 0.05;
        generate_classification(&spec, 2024)
    }

    #[test]
    fn compiled_tree_matches_boxed_on_training_rows() {
        let ds = hybrid_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let model = Model::SingleTree(tree);
        let compiled = CompiledModel::compile(&model, &ds.interner).unwrap();
        assert_eq!(compiled.kind(), "single_tree");
        assert_eq!(compiled.n_trees(), 1);
        assert!(compiled.table_bytes() > 0);
        let frame = RowFrame::from_dataset(&ds);
        let preds = compiled.predict_frame(&frame).unwrap();
        assert_eq!(preds.len(), ds.n_rows());
        assert!(preds.votes().is_none());
        for r in 0..ds.n_rows() {
            let expect = model.predict_row(&ds.row(r)).unwrap();
            assert_eq!(preds.label(r), expect, "row {r}");
            // The model-space value shim agrees too.
            assert_eq!(compiled.predict_row(&ds.row(r)).unwrap(), expect);
        }
    }

    #[test]
    fn tuned_caps_are_baked_structurally() {
        let ds = hybrid_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let full_nodes = tree.n_nodes();
        let model = Model::TunedTree {
            tree,
            max_depth: 3,
            min_split: 20,
        };
        let compiled = CompiledModel::compile(&model, &ds.interner).unwrap();
        // Capping prunes the table, not just the walk.
        assert!(compiled.n_nodes() < full_nodes);
        let frame = RowFrame::from_dataset(&ds);
        let preds = compiled.predict_frame(&frame).unwrap();
        for r in 0..ds.n_rows() {
            assert_eq!(
                preds.label(r),
                model.predict_row(&ds.row(r)).unwrap(),
                "row {r}"
            );
        }
    }

    #[test]
    fn forest_votes_sum_to_ensemble_size_and_match_labels() {
        let ds = hybrid_ds();
        let forest = Forest::fit(
            &ds,
            &ForestConfig {
                n_trees: 7,
                ..Default::default()
            },
        )
        .unwrap();
        let model = Model::Forest(forest);
        let compiled = CompiledModel::compile(&model, &ds.interner).unwrap();
        let frame = RowFrame::from_dataset(&ds);
        let preds = compiled.predict_frame(&frame).unwrap();
        let votes = preds.votes().expect("classification forest emits votes");
        assert_eq!(votes.n_trees(), 7);
        for r in (0..ds.n_rows()).step_by(23) {
            assert_eq!(preds.label(r), model.predict_row(&ds.row(r)).unwrap());
            let row_votes = votes.row(r);
            assert_eq!(row_votes.iter().sum::<u32>(), 7, "row {r}");
            let margin = votes.margin(r);
            assert!((0.0..=1.0).contains(&margin), "margin {margin}");
            // The label is an argmax of the reported votes.
            let max = *row_votes.iter().max().unwrap();
            let label_class = preds.label(r).as_class().unwrap() as usize;
            assert_eq!(row_votes[label_class], max);
        }
    }

    #[test]
    fn regression_forest_means_match_boxed() {
        let ds = generate_any(&SynthSpec::regression("cmpreg", 500, 5), 17);
        let forest = Forest::fit(
            &ds,
            &ForestConfig {
                n_trees: 5,
                ..Default::default()
            },
        )
        .unwrap();
        let model = Model::Forest(forest);
        let compiled = CompiledModel::compile(&model, &ds.interner).unwrap();
        let frame = RowFrame::from_dataset(&ds);
        let preds = compiled.predict_frame(&frame).unwrap();
        assert!(preds.votes().is_none());
        for r in (0..ds.n_rows()).step_by(13) {
            let a = preds.label(r).as_value().unwrap();
            let b = model.predict_row(&ds.row(r)).unwrap().as_value().unwrap();
            assert!((a - b).abs() <= 1e-12 * b.abs().max(1.0), "row {r}: {a} vs {b}");
        }
    }

    #[test]
    fn boosted_predictions_are_bit_identical_to_boxed() {
        use crate::tree::boost::{Boosted, BoostedConfig};
        let cfg = BoostedConfig {
            n_rounds: 8,
            ..Default::default()
        };
        // Multiclass (one-vs-rest) on hybrid data.
        let ds = hybrid_ds();
        let boosted = Boosted::fit(&ds, &cfg).unwrap();
        let model = Model::Boosted(boosted);
        let compiled = CompiledModel::compile(&model, &ds.interner).unwrap();
        assert_eq!(compiled.kind(), "boosted");
        assert_eq!(compiled.n_rounds(), 8);
        assert_eq!(compiled.n_trees(), 8 * 3);
        let frame = RowFrame::from_dataset(&ds);
        let preds = compiled.predict_frame(&frame).unwrap();
        assert!(preds.votes().is_none());
        for r in 0..ds.n_rows() {
            let expect = model.predict_row(&ds.row(r)).unwrap();
            assert_eq!(preds.label(r), expect, "row {r}");
            assert_eq!(compiled.predict_row(&ds.row(r)).unwrap(), expect);
        }

        // Regression: NodeLabel::Value compares with `==`, so this is a
        // bit-identity assertion, not an approximate one.
        let reg = generate_any(&SynthSpec::regression("cmpboost", 400, 5), 23);
        let boosted = Boosted::fit(&reg, &cfg).unwrap();
        let model = Model::Boosted(boosted);
        let compiled = CompiledModel::compile(&model, &reg.interner).unwrap();
        let frame = RowFrame::from_dataset(&reg);
        let preds = compiled.predict_frame(&frame).unwrap();
        for r in 0..reg.n_rows() {
            let expect = model.predict_row(&reg.row(r)).unwrap();
            assert_eq!(preds.label(r), expect, "row {r}");
        }
        // And thread count never changes boosted predictions either.
        let seq = compiled.predict_frame_threads(&frame, 1).unwrap();
        let par = compiled.predict_frame_threads(&frame, 8).unwrap();
        assert_eq!(seq.labels(), par.labels());
    }

    #[test]
    fn thread_count_never_changes_predictions() {
        let ds = hybrid_ds();
        let forest = Forest::fit(
            &ds,
            &ForestConfig {
                n_trees: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let compiled = CompiledModel::compile(&Model::Forest(forest), &ds.interner).unwrap();
        let frame = RowFrame::from_dataset(&ds);
        let seq = compiled.predict_frame_threads(&frame, 1).unwrap();
        let par = compiled.predict_frame_threads(&frame, 8).unwrap();
        assert_eq!(seq.labels(), par.labels());
        assert_eq!(
            seq.votes().unwrap().row(5),
            par.votes().unwrap().row(5)
        );
    }

    #[test]
    fn unseen_categories_route_like_missing() {
        let ds = hybrid_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let model = Model::SingleTree(tree);
        let compiled = CompiledModel::compile(&model, &ds.interner).unwrap();
        // A frame whose every cell is an unseen string must predict
        // exactly like an all-missing row.
        let mut b = crate::inference::RowFrameBuilder::new(ds.n_features());
        b.push_row(&vec![
            crate::inference::Cell::Str("never-seen");
            ds.n_features()
        ])
        .unwrap();
        let unseen = compiled.predict_frame(&b.finish()).unwrap();
        let missing_row = vec![Value::Missing; ds.n_features()];
        assert_eq!(
            unseen.label(0),
            model.predict_row(&missing_row).unwrap()
        );
    }

    #[test]
    fn arity_mismatch_is_typed() {
        let ds = hybrid_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let compiled = CompiledModel::compile(&Model::SingleTree(tree), &ds.interner).unwrap();
        let mut b = crate::inference::RowFrameBuilder::new(2);
        b.push_row(&[crate::inference::Cell::Num(1.0), crate::inference::Cell::Missing])
            .unwrap();
        assert!(matches!(
            compiled.predict_frame(&b.finish()),
            Err(UdtError::Predict(_))
        ));
        assert!(matches!(
            compiled.predict_row(&[Value::Num(1.0)]),
            Err(UdtError::Predict(_))
        ));
    }

    #[test]
    fn evaluate_frame_matches_boxed_evaluate() {
        let ds = hybrid_ds();
        let tree = Udt::builder().fit(&ds).unwrap();
        let model = Model::SingleTree(tree);
        let compiled = CompiledModel::compile(&model, &ds.interner).unwrap();
        let frame = RowFrame::from_dataset(&ds);
        let a = compiled.evaluate_frame(&frame, &ds.labels).unwrap().headline();
        let b = model.evaluate(&ds).unwrap().headline();
        assert!((a - b).abs() < 1e-12, "{a} vs {b}");
    }
}
