//! Property suite for the compiled inference path: on random hybrid
//! frames (numeric / categorical / missing / **unseen-string** cells),
//! `CompiledModel::predict_frame` must be prediction-for-prediction
//! identical to the boxed-node `predict_row` oracle, for all four model
//! families (single tree, tuned tree, forest, boosted) — and invariant
//! to the worker-thread count.

use udt::data::synth::{generate_any, SynthSpec};
use udt::data::value::Value;
use udt::inference::{Cell, RowFrameBuilder};
use udt::util::prop::{check, ensure, ensure_close, Config};
use udt::util::rng::Rng;
use udt::{Boosted, BoostedConfig, Forest, Model, SavedModel, Udt};

/// One generated request cell: what goes into the frame, and what the
/// boxed oracle must see for it (unseen strings behave exactly like
/// missing: no predicate can match them).
enum OwnedCell {
    Num(f64),
    Str(String),
    Missing,
}

impl OwnedCell {
    fn as_cell(&self) -> Cell<'_> {
        match self {
            OwnedCell::Num(x) => Cell::Num(*x),
            OwnedCell::Str(s) => Cell::Str(s),
            OwnedCell::Missing => Cell::Missing,
        }
    }

    /// The model-space value the boxed oracle predicts from.
    fn oracle_value(&self, ds: &udt::Dataset) -> Value {
        match self {
            OwnedCell::Num(x) => Value::Num(*x),
            OwnedCell::Str(s) => match ds.interner.get(s) {
                Some(id) => Value::Cat(id),
                None => Value::Missing, // unseen category ≡ missing routing
            },
            OwnedCell::Missing => Value::Missing,
        }
    }
}

/// Random request rows: dataset cells perturbed with unseen strings,
/// extra missing cells and fresh numerics.
fn random_request(
    rng: &mut Rng,
    ds: &udt::Dataset,
    n_rows: usize,
) -> (Vec<Vec<OwnedCell>>, Vec<Vec<Value>>) {
    let mut cells_rows = Vec::with_capacity(n_rows);
    let mut oracle_rows = Vec::with_capacity(n_rows);
    for _ in 0..n_rows {
        let src = rng.range(0, ds.n_rows());
        let mut cells = Vec::with_capacity(ds.n_features());
        for f in 0..ds.n_features() {
            let roll = rng.f64();
            let cell = if roll < 0.10 {
                OwnedCell::Str(format!("unseen-{}", rng.next_u64()))
            } else if roll < 0.18 {
                OwnedCell::Missing
            } else if roll < 0.26 {
                OwnedCell::Num(rng.f64_range(-100.0, 100.0))
            } else {
                match ds.value(f, src) {
                    Value::Num(x) => OwnedCell::Num(x),
                    Value::Cat(id) => OwnedCell::Str(ds.interner.name(id).to_string()),
                    Value::Missing => OwnedCell::Missing,
                }
            };
            cells.push(cell);
        }
        let oracle = cells.iter().map(|c| c.oracle_value(ds)).collect();
        cells_rows.push(cells);
        oracle_rows.push(oracle);
    }
    (cells_rows, oracle_rows)
}

fn labels_agree(
    got: udt::tree::NodeLabel,
    want: udt::tree::NodeLabel,
    ctx: &str,
) -> Result<(), String> {
    use udt::tree::NodeLabel;
    match (got, want) {
        (NodeLabel::Class(a), NodeLabel::Class(b)) => {
            ensure(a == b, format!("{ctx}: class {a} vs {b}"))
        }
        (NodeLabel::Value(a), NodeLabel::Value(b)) => ensure_close(a, b, 1e-9, ctx),
        (a, b) => Err(format!("{ctx}: label kinds differ ({a:?} vs {b:?})")),
    }
}

#[test]
fn compiled_frame_predictions_match_boxed_oracle_for_all_families() {
    check(
        "compiled ≡ boxed on hybrid frames",
        Config::default().cases(24).max_size(24).seed(0xC0_111),
        |rng, size| {
            // A small random hybrid problem (classification or regression).
            let n_rows = 60 + size * 12;
            let n_features = rng.range(2, 7);
            let regression = rng.chance(0.35);
            let mut spec = if regression {
                SynthSpec::regression("pi", n_rows, n_features)
            } else {
                SynthSpec::classification("pi", n_rows, n_features, rng.range(2, 5))
            };
            spec.cat_frac = rng.f64_range(0.0, 0.5);
            spec.hybrid_frac = rng.f64_range(0.0, 0.3);
            spec.missing_frac = rng.f64_range(0.0, 0.15);
            spec.cat_vocab = rng.range(2, 7);
            let ds = generate_any(&spec, rng.next_u64());

            let tree = Udt::builder()
                .fit(&ds)
                .map_err(|e| format!("train tree: {e}"))?;
            let forest = Forest::builder()
                .n_trees(rng.range(2, 5))
                .seed(rng.next_u64())
                .fit(&ds)
                .map_err(|e| format!("train forest: {e}"))?;
            let boosted = Boosted::fit(
                &ds,
                &BoostedConfig {
                    n_rounds: rng.range(2, 6),
                    max_depth: rng.range(2, 5),
                    subsample: rng.f64_range(0.5, 1.0),
                    seed: rng.next_u64(),
                    ..Default::default()
                },
            )
            .map_err(|e| format!("train boosted: {e}"))?;
            let families = [
                Model::SingleTree(tree.clone()),
                Model::TunedTree {
                    tree,
                    max_depth: rng.range(1, 8),
                    min_split: rng.range(0, 40),
                },
                Model::Forest(forest),
                Model::Boosted(boosted),
            ];

            let (cells_rows, oracle_rows) = random_request(rng, &ds, 40 + size * 4);
            for model in &families {
                let kind = model.kind();
                let compiled = SavedModel::new(model.clone(), &ds)
                    .compile()
                    .map_err(|e| format!("{kind}: compile: {e}"))?;
                let mut b = RowFrameBuilder::new(ds.n_features());
                for cells in &cells_rows {
                    let row: Vec<Cell> = cells.iter().map(OwnedCell::as_cell).collect();
                    b.push_row(&row).map_err(|e| format!("{kind}: {e}"))?;
                }
                let frame = b.finish();

                let preds = compiled
                    .predict_frame_threads(&frame, 1)
                    .map_err(|e| format!("{kind}: predict_frame: {e}"))?;
                let oracle = model
                    .predict_batch(&oracle_rows)
                    .map_err(|e| format!("{kind}: oracle: {e}"))?;
                ensure(
                    preds.len() == oracle.len(),
                    format!("{kind}: {} vs {} predictions", preds.len(), oracle.len()),
                )?;
                for (r, want) in oracle.iter().enumerate() {
                    labels_agree(preds.label(r), *want, &format!("{kind} row {r}"))?;
                    // The model-space shim agrees with the oracle too.
                    let shim = compiled
                        .predict_row(&oracle_rows[r])
                        .map_err(|e| format!("{kind}: shim: {e}"))?;
                    labels_agree(shim, *want, &format!("{kind} shim row {r}"))?;
                }

                // Thread count never changes predictions (chunk stitching).
                let par = compiled
                    .predict_frame_threads(&frame, 4)
                    .map_err(|e| format!("{kind}: parallel: {e}"))?;
                ensure(
                    par.labels() == preds.labels(),
                    format!("{kind}: parallel ≠ sequential"),
                )?;

                // Classification forests report votes consistent with the
                // winning label.
                if let (Model::Forest(f), Some(votes)) = (model, preds.votes()) {
                    for r in 0..preds.len() {
                        let row_votes = votes.row(r);
                        ensure(
                            row_votes.iter().sum::<u32>() as usize == f.trees.len(),
                            format!("{kind} row {r}: votes must sum to ensemble size"),
                        )?;
                        let label = preds.label(r).as_class().unwrap_or(0) as usize;
                        let max = *row_votes.iter().max().unwrap_or(&0);
                        ensure(
                            row_votes[label] == max,
                            format!("{kind} row {r}: label is not an argmax of votes"),
                        )?;
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn forest_batch_prediction_is_thread_invariant_on_random_data() {
    check(
        "forest predict_batch 1 ≡ N threads",
        Config::default().cases(12).max_size(16).seed(0xF0_222),
        |rng, size| {
            let mut spec = SynthSpec::classification("fb", 80 + size * 20, 5, 3);
            spec.cat_frac = rng.f64_range(0.0, 0.4);
            spec.missing_frac = rng.f64_range(0.0, 0.1);
            let ds = generate_any(&spec, rng.next_u64());
            let forest = Forest::builder()
                .n_trees(rng.range(2, 6))
                .seed(rng.next_u64())
                .fit(&ds)
                .map_err(|e| format!("train: {e}"))?;
            let rows: Vec<Vec<Value>> = (0..ds.n_rows()).map(|r| ds.row(r)).collect();
            let seq = forest.predict_batch_rows(&rows, 1);
            let par = forest.predict_batch_rows(&rows, 8);
            ensure(seq == par, "thread count changed forest batch predictions")?;
            for (r, label) in seq.iter().enumerate() {
                ensure(
                    *label == forest.predict_values(&rows[r]),
                    format!("row {r}: batch ≠ row-at-a-time"),
                )?;
            }
            Ok(())
        },
    );
}
