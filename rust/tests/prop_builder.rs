//! Property tests for the arena-frontier builder: the in-place-partition
//! builder must produce **node-for-node identical** trees to the oracle
//! paths on random hybrid (numeric/categorical/missing) datasets, for
//! classification and regression, at 1 and N threads, on full and subset
//! row sets — plus the zero-allocation arena accounting and the
//! predicate-routing oracle that independently re-derives every node's
//! sample count from the raw columns.

use udt::data::synth::{generate_any, SynthSpec};
use udt::data::Dataset;
use udt::tree::builder;
use udt::tree::{Backend, RegStrategy, TrainConfig, Tree};
use udt::util::prop::{check, ensure, Config};
use udt::util::rng::Rng;

/// Random hybrid dataset spec (classification when `n_classes > 0`).
fn random_spec(rng: &mut Rng, size: usize, regression: bool) -> SynthSpec {
    let n_rows = rng.range(60, size.max(80));
    let n_features = rng.range(2, 7);
    let mut spec = if regression {
        SynthSpec::regression("pb", n_rows, n_features)
    } else {
        SynthSpec::classification("pb", n_rows, n_features, rng.range(2, 5))
    };
    spec.cat_frac = rng.f64() * 0.5;
    spec.hybrid_frac = rng.f64() * 0.3;
    spec.missing_frac = rng.f64() * 0.15;
    spec.numeric_cardinality = rng.range(2, 40);
    spec.gt_depth = rng.range(2, 7);
    spec.noise = rng.f64() * 0.2;
    spec
}

/// Node-for-node structural equality (splits, children, samples, labels).
fn same_tree(a: &Tree, b: &Tree) -> Result<(), String> {
    ensure(
        a.n_nodes() == b.n_nodes(),
        format!("node counts differ: {} vs {}", a.n_nodes(), b.n_nodes()),
    )?;
    ensure(
        a.depth == b.depth,
        format!("depths differ: {} vs {}", a.depth, b.depth),
    )?;
    for (i, (x, y)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        ensure(
            x.split == y.split,
            format!("node {i} split: {:?} vs {:?}", x.split, y.split),
        )?;
        ensure(
            x.children == y.children,
            format!("node {i} children: {:?} vs {:?}", x.children, y.children),
        )?;
        ensure(
            x.n_samples == y.n_samples,
            format!("node {i} samples: {} vs {}", x.n_samples, y.n_samples),
        )?;
        ensure(
            x.label == y.label,
            format!("node {i} label: {:?} vs {:?}", x.label, y.label),
        )?;
    }
    Ok(())
}

/// Independent oracle: route every training row from the root using only
/// the raw columns and the tree's predicates, counting arrivals per
/// node. Catches any arena-partition corruption the selection-level
/// equivalence cannot see.
fn routed_counts(tree: &Tree, ds: &Dataset, rows: &[u32]) -> Vec<u32> {
    let mut counts = vec![0u32; tree.n_nodes()];
    for &r in rows {
        let mut id = 0usize; // root
        loop {
            counts[id] += 1;
            let node = &tree.nodes[id];
            match (&node.split, node.children) {
                (Some(pred), Some((pos, neg))) => {
                    let v = ds.value(pred.feature, r as usize);
                    id = if pred.op.eval(v) {
                        pos as usize
                    } else {
                        neg as usize
                    };
                }
                _ => break,
            }
        }
    }
    counts
}

fn check_routing(tree: &Tree, ds: &Dataset, rows: &[u32]) -> Result<(), String> {
    let counts = routed_counts(tree, ds, rows);
    for (i, node) in tree.nodes.iter().enumerate() {
        ensure(
            counts[i] == node.n_samples,
            format!(
                "node {i}: routed {} rows but builder recorded {}",
                counts[i], node.n_samples
            ),
        )?;
    }
    Ok(())
}

#[test]
fn arena_builder_matches_generic_oracle() {
    // Superfast on maintained arena lists vs the generic engine that
    // rescans the raw column per candidate: identical trees. Exercised
    // for classification and both regression strategies.
    for (regression, strategy) in [
        (false, RegStrategy::LabelSplit),
        (true, RegStrategy::LabelSplit),
        (true, RegStrategy::DirectSse),
    ] {
        check(
            &format!("arena ≡ generic (regression={regression}, {strategy:?})"),
            Config::default()
                .cases(25)
                .max_size(300)
                .seed(0xA12E_4A00 + regression as u64 + strategy as u64 * 2),
            |rng, size| {
                let spec = random_spec(rng, size, regression);
                let ds = generate_any(&spec, rng.next_u64());
                let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
                let fast = Tree::fit_rows(
                    &ds,
                    &rows,
                    &TrainConfig {
                        reg_strategy: strategy,
                        ..Default::default()
                    },
                )
                .map_err(|e| e.to_string())?;
                let slow = Tree::fit_rows(
                    &ds,
                    &rows,
                    &TrainConfig {
                        backend: Backend::Generic,
                        reg_strategy: strategy,
                        ..Default::default()
                    },
                )
                .map_err(|e| e.to_string())?;
                same_tree(&fast, &slow)?;
                check_routing(&fast, &ds, &rows)
            },
        );
    }
}

#[test]
fn thread_count_does_not_change_the_tree() {
    check(
        "1-thread ≡ N-thread build",
        Config::default().cases(20).max_size(300).seed(0x7123_AD01),
        |rng, size| {
            let regression = rng.chance(0.3);
            let spec = random_spec(rng, size, regression);
            let ds = generate_any(&spec, rng.next_u64());
            let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
            let seq = Tree::fit_rows(&ds, &rows, &TrainConfig::default())
                .map_err(|e| e.to_string())?;
            let par = Tree::fit_rows(
                &ds,
                &rows,
                &TrainConfig {
                    n_threads: 4,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            same_tree(&seq, &par)
        },
    );
}

#[test]
fn subset_fits_route_and_account_correctly() {
    check(
        "subset fit: routing oracle + zero arena growth",
        Config::default().cases(25).max_size(300).seed(0x5B5E_7F02),
        |rng, size| {
            let regression = rng.chance(0.5);
            let spec = random_spec(rng, size, regression);
            let ds = generate_any(&spec, rng.next_u64());
            let n = ds.n_rows();
            let mut all: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut all);
            let take = rng.range(20, n).min(n);
            let rows = &all[..take];
            let (tree, stats) =
                builder::fit_rows_with_stats(&ds, rows, &TrainConfig::default(), None)
                    .map_err(|e| e.to_string())?;
            ensure(
                stats.bytes_at_root > 0,
                "root arena accounting reported zero bytes",
            )?;
            ensure(
                stats.peak_bytes == stats.bytes_at_root
                    && stats.final_bytes == stats.bytes_at_root,
                format!(
                    "arena grew after root: root={} peak={} final={}",
                    stats.bytes_at_root, stats.peak_bytes, stats.final_bytes
                ),
            )?;
            ensure(
                tree.nodes[0].n_samples as usize == rows.len(),
                "root sample count != subset size",
            )?;
            check_routing(&tree, &ds, rows)
        },
    );
}

#[test]
fn masked_fit_matches_blanked_column_semantics_on_random_data() {
    check(
        "feature mask ≡ blanked columns",
        Config::default().cases(15).max_size(250).seed(0xFEA7_3A03),
        |rng, size| {
            let spec = random_spec(rng, size, false);
            let ds = generate_any(&spec, rng.next_u64());
            let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
            // Random mask keeping at least one feature active.
            let mut active: Vec<bool> = (0..ds.n_features())
                .map(|_| rng.chance(0.6))
                .collect();
            if !active.iter().any(|&a| a) {
                active[0] = true;
            }
            let masked =
                builder::fit_rows_masked(&ds, &rows, &TrainConfig::default(), Some(&active))
                    .map_err(|e| e.to_string())?;
            // Oracle: materialize the mask as all-Missing columns.
            let mut columns = ds.columns.clone();
            for (f, col) in columns.iter_mut().enumerate() {
                if !active[f] {
                    let blank = udt::data::column::Column::new(
                        col.name.clone(),
                        vec![udt::data::Value::Missing; col.len()],
                    );
                    *col = blank;
                }
            }
            let blanked = Dataset::new(
                ds.name.clone(),
                columns,
                ds.labels.clone(),
                std::sync::Arc::clone(&ds.interner),
            )
            .map_err(|e| e.to_string())?;
            let oracle = Tree::fit_rows(&blanked, &rows, &TrainConfig::default())
                .map_err(|e| e.to_string())?;
            same_tree(&masked, &oracle)
        },
    );
}
