//! Cross-module integration: full train→tune→prune→evaluate pipelines on
//! registry datasets, CSV ingestion, model serialization, the prediction
//! server, and failure injection — all through the unified model surface.

use udt::coordinator::pipeline::{run_pipeline, Quality};
use udt::coordinator::serve::Server;
use udt::data::csv::{load_csv_str, to_csv_string, CsvOptions};
use udt::data::dataset::TaskKind;
use udt::data::synth::{generate_any, registry, SynthSpec};
use udt::tree::tuning::TuneGrid;
use udt::tree::{Backend, RegStrategy};
use udt::util::json::Json;
use udt::{Estimator, Model, SavedModel, Tree, Udt, UdtError};

#[test]
fn pipeline_on_scaled_registry_datasets() {
    // A cross-section of Table 6 shapes at 5% scale: hybrid-heavy,
    // many-class, wide, and numeric-heavy datasets. Thresholds reflect
    // each dataset's difficulty at this tiny scale (letter has 26 classes
    // and a deep ground truth — 1000 rows barely scratch it).
    for (name, min_acc) in [
        ("adult", 0.5),
        ("letter", 0.12),
        ("nursery", 0.5),
        ("churn_modeling", 0.5),
    ] {
        let entry = registry::find(name).unwrap();
        let ds = generate_any(&entry.spec.scaled(0.05), 11);
        let cfg = Udt::builder().build().unwrap();
        let rep = run_pipeline(&ds, &cfg, &TuneGrid::default(), 1).unwrap();
        match rep.quality {
            Quality::Accuracy(a) => {
                assert!(a > min_acc, "{name}: accuracy {a}");
            }
            _ => panic!("classification expected"),
        }
        assert!(rep.tuned_nodes <= rep.full_nodes, "{name}");
        // Settings = depth sweep + distinct min_split grid values (the
        // duplicate grid points of small training sets count once).
        assert_eq!(
            rep.n_settings,
            rep.full_depth as usize
                + udt::tree::tuning::distinct_split_grid(rep.n_train, &TuneGrid::default()).len(),
            "{name}"
        );
        assert!(rep.n_settings > rep.full_depth as usize, "{name}");
    }
}

#[test]
fn pipeline_on_scaled_regression_datasets() {
    for name in ["wine_quality", "bike_sharing_hour"] {
        let entry = registry::find(name).unwrap();
        let ds = generate_any(&entry.spec.scaled(0.05), 13);
        let cfg = Udt::builder().build().unwrap();
        let rep = run_pipeline(&ds, &cfg, &TuneGrid::default(), 2).unwrap();
        match rep.quality {
            Quality::Regression { mae, rmse } => {
                assert!(mae.is_finite() && rmse.is_finite() && mae <= rmse + 1e-9, "{name}");
            }
            _ => panic!("regression expected"),
        }
    }
}

#[test]
fn pipeline_honors_a_custom_tune_grid() {
    let entry = registry::find("churn_modeling").unwrap();
    let ds = generate_any(&entry.spec.scaled(0.05), 17);
    let cfg = Udt::builder().build().unwrap();
    let small_grid = TuneGrid {
        min_split_steps: 10,
        ..Default::default()
    };
    let rep_small = run_pipeline(&ds, &cfg, &small_grid, 1).unwrap();
    let rep_default = run_pipeline(&ds, &cfg, &TuneGrid::default(), 1).unwrap();
    // Grid size drives the number of evaluated settings — but only up
    // to the distinct integer min_split values it can reach (duplicate
    // grid points are swept once, so a 200-step grid over a small
    // training set no longer inflates the count).
    let small_probes = udt::tree::tuning::distinct_split_grid(rep_small.n_train, &small_grid);
    let default_probes =
        udt::tree::tuning::distinct_split_grid(rep_default.n_train, &TuneGrid::default());
    assert!(
        default_probes.len() > small_probes.len(),
        "finer grid must probe more distinct settings ({} vs {})",
        default_probes.len(),
        small_probes.len()
    );
    assert_eq!(
        rep_default.n_settings - rep_small.n_settings,
        default_probes.len() - small_probes.len(),
        "grid size must drive the number of evaluated settings"
    );
}

#[test]
fn csv_train_predict_round_trip() {
    // Generate → CSV → parse → train → serialize → reload → same preds.
    let mut spec = SynthSpec::classification("csvtest", 400, 5, 3);
    spec.cat_frac = 0.4;
    spec.missing_frac = 0.05;
    let ds0 = generate_any(&spec, 17);
    let csv = to_csv_string(&ds0);
    let ds = load_csv_str("csvtest", &csv, &CsvOptions::default()).unwrap();
    assert_eq!(ds.n_rows(), 400);
    assert_eq!(ds.task(), TaskKind::Classification);

    let tree = Udt::builder().fit(&ds).unwrap();
    let saved = SavedModel::new(Model::SingleTree(tree), &ds);
    let text = saved.to_json().to_pretty();
    let back = SavedModel::from_json(&Json::parse(&text).unwrap()).unwrap();
    for r in (0..ds.n_rows()).step_by(11) {
        let row = ds.row(r);
        assert_eq!(
            back.model.predict_row(&row).unwrap(),
            saved.model.predict_row(&row).unwrap()
        );
    }
}

#[test]
fn server_predictions_match_model() {
    let mut spec = SynthSpec::classification("srv", 600, 4, 2);
    spec.cat_frac = 0.25;
    let ds = generate_any(&spec, 19);
    let tree = Udt::builder().fit(&ds).unwrap();
    let saved = SavedModel::new(Model::SingleTree(tree), &ds);
    let class_names = saved.schema.class_names.clone();
    let model = saved.model.clone();
    let server = Server::new(saved).unwrap();

    for r in (0..ds.n_rows()).step_by(29) {
        let row = ds.row(r);
        let cells: Vec<String> = row
            .iter()
            .map(|v| match v {
                udt::data::value::Value::Num(x) => format!("{x}"),
                udt::data::value::Value::Cat(c) => {
                    format!("\"{}\"", ds.interner.name(*c))
                }
                udt::data::value::Value::Missing => "null".to_string(),
            })
            .collect();
        let req = format!("[{}]", cells.join(","));
        let resp = server.handle(&req);
        let expected = model.predict_row(&row).unwrap().as_class().unwrap();
        let expected_name = &class_names[expected as usize];
        assert_eq!(resp, format!("\"{expected_name}\""), "row {r}");
    }
}

#[test]
fn backends_build_identical_trees_on_hybrid_data() {
    let mut spec = SynthSpec::classification("bk", 800, 6, 3);
    spec.cat_frac = 0.3;
    spec.missing_frac = 0.05;
    let ds = generate_any(&spec, 23);
    let t_fast = Udt::builder().fit(&ds).unwrap();
    let t_slow = Udt::builder().backend(Backend::Generic).fit(&ds).unwrap();
    assert_eq!(t_fast.n_nodes(), t_slow.n_nodes());
    for (a, b) in t_fast.nodes.iter().zip(&t_slow.nodes) {
        assert_eq!(a.split, b.split);
        assert_eq!(a.label, b.label);
    }
}

#[test]
fn regression_strategies_comparable_quality() {
    let spec = SynthSpec::regression("regcmp", 2500, 8);
    let ds = generate_any(&spec, 29);
    let (train, _, test) = ds.split_indices(0.8, 0.1, 5);
    let mut rmses = Vec::new();
    for strategy in [RegStrategy::LabelSplit, RegStrategy::DirectSse] {
        let cfg = Udt::builder().reg_strategy(strategy).build().unwrap();
        let tree = Tree::fit_rows(&ds, &train, &cfg).unwrap();
        let (_, rmse) = tree.regression_error(&ds, &test).unwrap();
        rmses.push(rmse);
    }
    // The paper's label-split strategy should be in the same quality
    // ballpark as direct SSE (within 2.5×).
    assert!(
        rmses[0] < rmses[1] * 2.5 && rmses[1] < rmses[0] * 2.5,
        "label-split {} vs direct {}",
        rmses[0],
        rmses[1]
    );
}

#[test]
fn failure_injection_empty_and_degenerate_inputs() {
    // Empty row set.
    let spec = SynthSpec::classification("fi", 50, 3, 2);
    let ds = generate_any(&spec, 31);
    let cfg = Udt::builder().build().unwrap();
    assert!(matches!(
        Tree::fit_rows(&ds, &[], &cfg),
        Err(UdtError::Data(_))
    ));

    // max_depth = 0 rejected by the builder, not a panic downstream.
    assert!(matches!(
        Udt::builder().max_depth(0).fit(&ds),
        Err(UdtError::InvalidConfig(_))
    ));

    // Single-row training set → single leaf.
    let t = Tree::fit_rows(&ds, &[0], &cfg).unwrap();
    assert_eq!(t.n_nodes(), 1);

    // All-missing feature column still trains (on the other columns).
    let mut columns = ds.columns.clone();
    let blank = udt::data::column::Column::new(
        columns[0].name.clone(),
        vec![udt::data::value::Value::Missing; columns[0].len()],
    );
    columns[0] = blank;
    let ds2 = udt::Dataset::new("fi2", columns, ds.labels.clone(), ds.interner.clone()).unwrap();
    let t2 = Udt::builder().fit(&ds2).unwrap();
    assert!(t2.n_nodes() >= 1);

    // Task mismatch is typed, not a panic.
    let reg = generate_any(&SynthSpec::regression("fir", 60, 3), 33);
    assert!(matches!(
        t.evaluate(&reg),
        Err(UdtError::TaskMismatch { .. })
    ));

    // Malformed CSV errors.
    assert!(load_csv_str("bad", "a,b\n", &CsvOptions::default()).is_err());
    assert!(load_csv_str("bad", "", &CsvOptions::default()).is_err());
}

#[test]
fn chi2_and_gini_criteria_train_reasonably() {
    let spec = SynthSpec::classification("crit", 1200, 6, 3);
    let ds = generate_any(&spec, 37);
    for crit in [
        udt::selection::heuristic::ClassCriterion::Gini,
        udt::selection::heuristic::ClassCriterion::ChiSquare,
    ] {
        let tree = Udt::builder().criterion(crit).fit(&ds).unwrap();
        let acc = tree.accuracy(&ds).unwrap();
        assert!(acc > 0.9, "{}: {acc}", crit.name());
    }
}
