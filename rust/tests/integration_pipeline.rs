//! Cross-module integration: full train→tune→prune→evaluate pipelines on
//! registry datasets, CSV ingestion, tree serialization, the prediction
//! server, and failure injection.

use udt::coordinator::pipeline::{run_pipeline, Quality};
use udt::coordinator::serve::Server;
use udt::data::csv::{load_csv_str, to_csv_string, CsvOptions};
use udt::data::dataset::TaskKind;
use udt::data::synth::{generate_any, registry, SynthSpec};
use udt::tree::{serialize, Backend, RegStrategy, TrainConfig, Tree};
use udt::util::json::Json;

#[test]
fn pipeline_on_scaled_registry_datasets() {
    // A cross-section of Table 6 shapes at 5% scale: hybrid-heavy,
    // many-class, wide, and numeric-heavy datasets. Thresholds reflect
    // each dataset's difficulty at this tiny scale (letter has 26 classes
    // and a deep ground truth — 1000 rows barely scratch it).
    for (name, min_acc) in [
        ("adult", 0.5),
        ("letter", 0.12),
        ("nursery", 0.5),
        ("churn_modeling", 0.5),
    ] {
        let entry = registry::find(name).unwrap();
        let ds = generate_any(&entry.spec.scaled(0.05), 11);
        let rep = run_pipeline(&ds, &TrainConfig::default(), 1).unwrap();
        match rep.quality {
            Quality::Accuracy(a) => {
                assert!(a > min_acc, "{name}: accuracy {a}");
            }
            _ => panic!("classification expected"),
        }
        assert!(rep.tuned_nodes <= rep.full_nodes, "{name}");
        assert!(rep.n_settings > 100, "{name}");
    }
}

#[test]
fn pipeline_on_scaled_regression_datasets() {
    for name in ["wine_quality", "bike_sharing_hour"] {
        let entry = registry::find(name).unwrap();
        let ds = generate_any(&entry.spec.scaled(0.05), 13);
        let rep = run_pipeline(&ds, &TrainConfig::default(), 2).unwrap();
        match rep.quality {
            Quality::Regression { mae, rmse } => {
                assert!(mae.is_finite() && rmse.is_finite() && mae <= rmse + 1e-9, "{name}");
            }
            _ => panic!("regression expected"),
        }
    }
}

#[test]
fn csv_train_predict_round_trip() {
    // Generate → CSV → parse → train → serialize → reload → same preds.
    let mut spec = SynthSpec::classification("csvtest", 400, 5, 3);
    spec.cat_frac = 0.4;
    spec.missing_frac = 0.05;
    let ds0 = generate_any(&spec, 17);
    let csv = to_csv_string(&ds0);
    let ds = load_csv_str("csvtest", &csv, &CsvOptions::default()).unwrap();
    assert_eq!(ds.n_rows(), 400);
    assert_eq!(ds.task(), TaskKind::Classification);

    let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
    let json_text = serialize::to_json(&tree, &ds.interner).to_pretty();
    let mut interner = ds.interner.clone();
    let tree2 = serialize::from_json(&Json::parse(&json_text).unwrap(), &mut interner).unwrap();
    for r in (0..ds.n_rows()).step_by(11) {
        assert_eq!(
            udt::tree::predict::predict_ds(&tree, &ds, r, usize::MAX, 0),
            udt::tree::predict::predict_ds(&tree2, &ds, r, usize::MAX, 0)
        );
    }
}

#[test]
fn server_predictions_match_tree() {
    let mut spec = SynthSpec::classification("srv", 600, 4, 2);
    spec.cat_frac = 0.25;
    let ds = generate_any(&spec, 19);
    let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
    let server = Server::new(tree.clone(), ds.interner.clone(), ds.class_names.clone());

    for r in (0..ds.n_rows()).step_by(29) {
        let row = ds.row(r);
        let cells: Vec<String> = row
            .iter()
            .map(|v| match v {
                udt::data::value::Value::Num(x) => format!("{x}"),
                udt::data::value::Value::Cat(c) => {
                    format!("\"{}\"", ds.interner.name(*c))
                }
                udt::data::value::Value::Missing => "null".to_string(),
            })
            .collect();
        let req = format!("[{}]", cells.join(","));
        let resp = server.handle(&req);
        let expected = udt::tree::predict::predict_row(&tree, &row, usize::MAX, 0).class();
        let expected_name = &ds.class_names[expected as usize];
        assert_eq!(resp, format!("\"{expected_name}\""), "row {r}");
    }
}

#[test]
fn backends_build_identical_trees_on_hybrid_data() {
    let mut spec = SynthSpec::classification("bk", 800, 6, 3);
    spec.cat_frac = 0.3;
    spec.missing_frac = 0.05;
    let ds = generate_any(&spec, 23);
    let t_fast = Tree::fit(&ds, &TrainConfig::default()).unwrap();
    let t_slow = Tree::fit(
        &ds,
        &TrainConfig {
            backend: Backend::Generic,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(t_fast.n_nodes(), t_slow.n_nodes());
    for (a, b) in t_fast.nodes.iter().zip(&t_slow.nodes) {
        assert_eq!(a.split, b.split);
        assert_eq!(a.label, b.label);
    }
}

#[test]
fn regression_strategies_comparable_quality() {
    let spec = SynthSpec::regression("regcmp", 2500, 8);
    let ds = generate_any(&spec, 29);
    let (train, _, test) = ds.split_indices(0.8, 0.1, 5);
    let mut rmses = Vec::new();
    for strategy in [RegStrategy::LabelSplit, RegStrategy::DirectSse] {
        let tree = Tree::fit_rows(
            &ds,
            &train,
            &TrainConfig {
                reg_strategy: strategy,
                ..Default::default()
            },
        )
        .unwrap();
        let (_, rmse) = tree.regression_error(&ds, &test);
        rmses.push(rmse);
    }
    // The paper's label-split strategy should be in the same quality
    // ballpark as direct SSE (within 2.5×).
    assert!(
        rmses[0] < rmses[1] * 2.5 && rmses[1] < rmses[0] * 2.5,
        "label-split {} vs direct {}",
        rmses[0],
        rmses[1]
    );
}

#[test]
fn failure_injection_empty_and_degenerate_inputs() {
    // Empty row set.
    let spec = SynthSpec::classification("fi", 50, 3, 2);
    let ds = generate_any(&spec, 31);
    assert!(Tree::fit_rows(&ds, &[], &TrainConfig::default()).is_err());

    // max_depth = 0 rejected.
    assert!(Tree::fit(
        &ds,
        &TrainConfig {
            max_depth: 0,
            ..Default::default()
        }
    )
    .is_err());

    // Single-row training set → single leaf.
    let t = Tree::fit_rows(&ds, &[0], &TrainConfig::default()).unwrap();
    assert_eq!(t.n_nodes(), 1);

    // All-missing feature column still trains (on the other columns).
    let mut columns = ds.columns.clone();
    for v in &mut columns[0].values {
        *v = udt::data::value::Value::Missing;
    }
    let ds2 = udt::Dataset::new("fi2", columns, ds.labels.clone(), ds.interner.clone()).unwrap();
    let t2 = Tree::fit(&ds2, &TrainConfig::default()).unwrap();
    assert!(t2.n_nodes() >= 1);

    // Malformed CSV errors.
    assert!(load_csv_str("bad", "a,b\n", &CsvOptions::default()).is_err());
    assert!(load_csv_str("bad", "", &CsvOptions::default()).is_err());
}

#[test]
fn chi2_and_gini_criteria_train_reasonably() {
    let spec = SynthSpec::classification("crit", 1200, 6, 3);
    let ds = generate_any(&spec, 37);
    for crit in [
        udt::selection::heuristic::ClassCriterion::Gini,
        udt::selection::heuristic::ClassCriterion::ChiSquare,
    ] {
        let tree = Tree::fit(
            &ds,
            &TrainConfig {
                criterion: crit,
                ..Default::default()
            },
        )
        .unwrap();
        let acc = tree.accuracy(&ds);
        assert!(acc > 0.9, "{}: {acc}", crit.name());
    }
}
