//! The unified model surface, end to end: serde round-trips of all four
//! `Model` variants (schema + interner included), TCP serving of a tuned
//! tree, a forest and a boosted ensemble (single, batch, named-registry
//! and stats requests over the wire), and builder validation (bad
//! configs are typed errors, not panics). Serving runs on the compiled
//! inference path throughout.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use udt::coordinator::registry::ModelRegistry;
use udt::coordinator::serve::Server;
use udt::data::synth::{generate_any, generate_classification, SynthSpec};
use udt::data::value::Value;
use udt::tree::tuning::{tune, TuneGrid};
use udt::util::json::Json;
use udt::{Boosted, BoostedConfig, Estimator, Forest, Model, SavedModel, Tree, Udt, UdtError};

fn hybrid_ds() -> udt::Dataset {
    let mut spec = SynthSpec::classification("mapi", 1200, 6, 3);
    spec.cat_frac = 0.35;
    spec.missing_frac = 0.05;
    spec.noise = 0.15;
    generate_classification(&spec, 4242)
}

/// Serialize → parse → deserialize, asserting the document is versioned
/// and self-contained (schema + interner travel with the model).
fn round_trip(saved: &SavedModel) -> SavedModel {
    let json = saved.to_json();
    assert_eq!(
        json.get("format").and_then(Json::as_str),
        Some("udt-model"),
        "document must carry the format tag"
    );
    assert!(json.get("schema").is_some(), "schema must be bundled");
    assert!(json.get("interner").is_some(), "interner must be bundled");
    let text = json.to_pretty();
    SavedModel::from_json(&Json::parse(&text).unwrap()).unwrap()
}

#[test]
fn all_four_model_variants_round_trip_with_schema_and_interner() {
    let ds = hybrid_ds();
    let tree = Udt::builder().fit(&ds).unwrap();
    let (train, val, _) = ds.split_indices(0.8, 0.1, 7);
    let full = Tree::fit_rows(&ds, &train, &Udt::builder().build().unwrap()).unwrap();
    let tuned = tune(&full, &ds, &val, train.len(), &TuneGrid::default()).unwrap();
    let forest = Forest::builder().n_trees(4).fit(&ds).unwrap();
    let boosted = Boosted::fit(
        &ds,
        &BoostedConfig {
            n_rounds: 5,
            ..Default::default()
        },
    )
    .unwrap();

    let variants = [
        SavedModel::new(Model::SingleTree(tree), &ds),
        SavedModel::new(
            Model::TunedTree {
                tree: full,
                max_depth: tuned.best_max_depth,
                min_split: tuned.best_min_split,
            },
            &ds,
        ),
        SavedModel::new(Model::Forest(forest), &ds),
        SavedModel::new(Model::Boosted(boosted), &ds),
    ];

    for saved in &variants {
        let back = round_trip(saved);
        assert_eq!(back.model.kind(), saved.model.kind());
        assert_eq!(back.schema.feature_names, saved.schema.feature_names);
        assert_eq!(back.schema.class_names, saved.schema.class_names);
        assert_eq!(back.interner.len(), saved.interner.len());
        for r in (0..ds.n_rows()).step_by(31) {
            let row = ds.row(r);
            assert_eq!(
                back.model.predict_row(&row).unwrap(),
                saved.model.predict_row(&row).unwrap(),
                "{} row {r}",
                saved.model.kind()
            );
        }
    }
}

/// Start a server, run `f` against the live socket, shut down cleanly.
fn with_tcp_server(saved: SavedModel, f: impl FnOnce(&mut TcpStream, &mut BufReader<TcpStream>)) {
    let server = Server::new(saved).unwrap();
    with_server(server, f)
}

fn with_server(
    server: std::sync::Arc<Server>,
    f: impl FnOnce(&mut TcpStream, &mut BufReader<TcpStream>),
) {
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let handle = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    f(&mut stream, &mut reader);
    stream.write_all(b"\"shutdown\"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    handle.join().unwrap();
}

fn request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> String {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    resp.trim().to_string()
}

fn json_cells(ds: &udt::Dataset, r: usize) -> String {
    let cells: Vec<String> = ds
        .row(r)
        .iter()
        .map(|v| match v {
            Value::Num(x) => format!("{x}"),
            Value::Cat(c) => format!("\"{}\"", ds.interner.name(*c).replace('"', "\\\"")),
            Value::Missing => "null".to_string(),
        })
        .collect();
    format!("[{}]", cells.join(","))
}

/// The response the server should give for one locally-predicted label.
fn expected_response(saved: &SavedModel, ds: &udt::Dataset, r: usize) -> String {
    let label = saved.model.predict_row(&ds.row(r)).unwrap();
    let class = label.as_class().unwrap();
    match saved.schema.class_name(class) {
        Some(name) => format!("\"{name}\""),
        None => format!("{class}"),
    }
}

#[test]
fn tcp_serving_a_tuned_tree_loaded_from_json() {
    let ds = hybrid_ds();
    let (train, val, _) = ds.split_indices(0.8, 0.1, 11);
    let full = Tree::fit_rows(&ds, &train, &Udt::builder().build().unwrap()).unwrap();
    let tuned = tune(&full, &ds, &val, train.len(), &TuneGrid::default()).unwrap();
    let saved = round_trip(&SavedModel::new(
        Model::TunedTree {
            tree: full,
            max_depth: tuned.best_max_depth,
            min_split: tuned.best_min_split,
        },
        &ds,
    ));
    let local = saved.clone();

    with_tcp_server(saved, |stream, reader| {
        // Single-row requests.
        for r in [0usize, 97, 501] {
            let resp = request(stream, reader, &json_cells(&ds, r));
            assert_eq!(resp, expected_response(&local, &ds, r), "row {r}");
        }
        // Batch request.
        let rows: Vec<usize> = (0..10).map(|i| i * 13).collect();
        let batch = format!(
            "[{}]",
            rows.iter()
                .map(|&r| json_cells(&ds, r))
                .collect::<Vec<_>>()
                .join(",")
        );
        let resp = request(stream, reader, &batch);
        let parsed = Json::parse(&resp).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), rows.len());
        for (&r, got) in rows.iter().zip(arr) {
            assert_eq!(got.to_string(), expected_response(&local, &ds, r));
        }
        // Stats identify the model family and count the work done —
        // per-model, and control lines don't pollute predict counters.
        let stats = Json::parse(&request(stream, reader, "\"stats\"")).unwrap();
        let model = stats.get("models").unwrap().get("default").unwrap();
        assert_eq!(model.get("kind").unwrap().as_str().unwrap(), "tuned_tree");
        assert!(model.get("predictions").unwrap().as_f64().unwrap() >= 13.0);
        assert!(stats.get("predict_requests").unwrap().as_f64().unwrap() >= 4.0);
    });
}

#[test]
fn tcp_serving_a_forest_loaded_from_json() {
    let ds = hybrid_ds();
    let forest = Forest::builder().n_trees(5).sample_frac(0.6).fit(&ds).unwrap();
    let saved = round_trip(&SavedModel::new(Model::Forest(forest), &ds));
    let local = saved.clone();

    with_tcp_server(saved, |stream, reader| {
        for r in [3usize, 42, 777] {
            let resp = request(stream, reader, &json_cells(&ds, r));
            assert_eq!(resp, expected_response(&local, &ds, r), "row {r}");
        }
        let batch = format!("[{},{}]", json_cells(&ds, 8), json_cells(&ds, 9));
        let parsed = Json::parse(&request(stream, reader, &batch)).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 2);
        let stats = Json::parse(&request(stream, reader, "\"stats\"")).unwrap();
        let model = stats.get("models").unwrap().get("default").unwrap();
        assert_eq!(model.get("kind").unwrap().as_str().unwrap(), "forest");
        assert!(model.get("nodes").unwrap().as_f64().unwrap() > 0.0);
    });
}

#[test]
fn tcp_serving_a_boosted_ensemble_loaded_from_json() {
    let ds = hybrid_ds();
    assert_eq!(ds.sort_index_builds(), 0);
    let boosted = Boosted::fit(
        &ds,
        &BoostedConfig {
            n_rounds: 6,
            subsample: 0.9,
            ..Default::default()
        },
    )
    .unwrap();
    // A full multi-round (6 × 3 one-vs-rest channels = 18 trees) boost
    // run sorts each column exactly once.
    assert_eq!(ds.sort_index_builds(), 1);
    assert_eq!(boosted.trees.len(), 18);
    let saved = round_trip(&SavedModel::new(Model::Boosted(boosted), &ds));
    let local = saved.clone();

    with_tcp_server(saved, |stream, reader| {
        for r in [2usize, 55, 431] {
            let resp = request(stream, reader, &json_cells(&ds, r));
            assert_eq!(resp, expected_response(&local, &ds, r), "row {r}");
        }
        let batch = format!("[{},{}]", json_cells(&ds, 4), json_cells(&ds, 5));
        let parsed = Json::parse(&request(stream, reader, &batch)).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 2);
        // Stats identify the boosted family and its round count.
        let stats = Json::parse(&request(stream, reader, "\"stats\"")).unwrap();
        let model = stats.get("models").unwrap().get("default").unwrap();
        assert_eq!(model.get("kind").unwrap().as_str().unwrap(), "boosted");
        assert_eq!(model.get("rounds").unwrap().as_f64().unwrap(), 6.0);
        assert_eq!(model.get("trees").unwrap().as_f64().unwrap(), 18.0);
        assert!(model.get("predictions").unwrap().as_f64().unwrap() >= 5.0);
    });
}

#[test]
fn tcp_registry_serves_named_models_and_legacy_requests() {
    let ds = hybrid_ds();
    let tree_saved = SavedModel::new(
        Model::SingleTree(Udt::builder().fit(&ds).unwrap()),
        &ds,
    );
    let forest_saved = SavedModel::new(
        Model::Forest(Forest::builder().n_trees(4).fit(&ds).unwrap()),
        &ds,
    );
    let tree_local = tree_saved.clone();
    let forest_local = forest_saved.clone();

    let registry = ModelRegistry::new();
    registry.load("churn", tree_saved).unwrap();
    registry.load("risk", forest_saved).unwrap();
    registry.alias("prod", "risk").unwrap();
    let server = Server::with_registry(registry);

    with_server(server, |stream, reader| {
        // Legacy bare-array requests hit the default (first-loaded) model.
        for r in [5usize, 71, 301] {
            let resp = request(stream, reader, &json_cells(&ds, r));
            assert_eq!(resp, expected_response(&tree_local, &ds, r), "row {r}");
        }
        // Named addressing reaches the forest — prediction-for-prediction
        // equal to the boxed ensemble.
        let rows: Vec<usize> = (0..8).map(|i| i * 29).collect();
        let batch = rows
            .iter()
            .map(|&r| json_cells(&ds, r))
            .collect::<Vec<_>>()
            .join(",");
        let resp = request(
            stream,
            reader,
            &format!("{{\"model\":\"risk\",\"rows\":[{batch}]}}"),
        );
        let parsed = Json::parse(&resp).unwrap();
        assert_eq!(parsed.get("model").unwrap().as_str().unwrap(), "risk");
        let labels = parsed.get("labels").unwrap().as_arr().unwrap();
        assert_eq!(labels.len(), rows.len());
        for (&r, got) in rows.iter().zip(labels) {
            assert_eq!(
                got.to_string(),
                expected_response(&forest_local, &ds, r),
                "row {r}"
            );
        }
        // Aliases resolve; single-row object form returns a 1-label array.
        let resp = request(
            stream,
            reader,
            &format!("{{\"model\":\"prod\",\"rows\":{}}}", json_cells(&ds, 13)),
        );
        let parsed = Json::parse(&resp).unwrap();
        assert_eq!(parsed.get("model").unwrap().as_str().unwrap(), "risk");
        assert_eq!(parsed.get("labels").unwrap().as_arr().unwrap().len(), 1);
        // Unknown model names are protocol errors.
        let resp = request(stream, reader, "{\"model\":\"gone\",\"rows\":[[1,2,3,4,5,6]]}");
        assert!(resp.contains("error"), "{resp}");
        // The registry listing and per-model stats see both models.
        let models = Json::parse(&request(stream, reader, "\"models\"")).unwrap();
        let names = models.get("models").unwrap().as_arr().unwrap();
        assert_eq!(names.len(), 2);
        assert_eq!(models.get("default").unwrap().as_str().unwrap(), "churn");
        let stats = Json::parse(&request(stream, reader, "\"stats\"")).unwrap();
        let churn = stats.get("models").unwrap().get("churn").unwrap();
        let risk = stats.get("models").unwrap().get("risk").unwrap();
        assert_eq!(churn.get("kind").unwrap().as_str().unwrap(), "single_tree");
        assert_eq!(risk.get("kind").unwrap().as_str().unwrap(), "forest");
        assert!(churn.get("predictions").unwrap().as_f64().unwrap() >= 3.0);
        assert!(risk.get("predictions").unwrap().as_f64().unwrap() >= 9.0);
        assert!(risk.get("rows_per_sec").unwrap().as_f64().unwrap() >= 0.0);
    });
}

#[test]
fn builders_reject_bad_configs_with_typed_errors() {
    let ds = hybrid_ds();
    // Tree builder.
    assert!(matches!(
        Udt::builder().max_depth(0).build(),
        Err(UdtError::InvalidConfig(_))
    ));
    assert!(matches!(
        Udt::builder().min_samples_split(0).build(),
        Err(UdtError::InvalidConfig(_))
    ));
    assert!(matches!(
        Udt::builder().min_gain(f64::INFINITY).fit(&ds),
        Err(UdtError::InvalidConfig(_))
    ));
    // Forest builder.
    assert!(matches!(
        Forest::builder().n_trees(0).fit(&ds),
        Err(UdtError::InvalidConfig(_))
    ));
    assert!(matches!(
        Forest::builder().feature_frac(-0.5).build(),
        Err(UdtError::InvalidConfig(_))
    ));
    assert!(matches!(
        Forest::builder().sample_frac(2.0).build(),
        Err(UdtError::InvalidConfig(_))
    ));
    // Valid builds still work.
    let tree = Udt::builder().max_depth(4).fit(&ds).unwrap();
    assert!(tree.depth <= 4);
}

#[test]
fn malformed_model_documents_surface_as_model_errors() {
    for doc in [
        r#"{"format":"udt-model","version":1}"#,
        r#"{"format":"udt-model","version":2,"kind":"single_tree",
            "schema":{"features":[],"classes":[]},"interner":[]}"#,
        // Split feature out of range must be rejected at load, not panic
        // at predict.
        r#"{"format":"udt-model","version":1,"kind":"single_tree",
            "schema":{"features":[{"name":"f0","kind":"numeric"}],"classes":[]},
            "interner":[],
            "tree":{"task":"classification","n_features":1,"depth":2,
                    "nodes":[{"n":2,"d":1,"label":0,"op":"le","operand":1,
                              "feature":9,"children":[1,2]},
                             {"n":1,"d":2,"label":0},
                             {"n":1,"d":2,"label":1}]}}"#,
    ] {
        let parsed = Json::parse(doc).unwrap();
        assert!(
            matches!(SavedModel::from_json(&parsed), Err(UdtError::Model(_))),
            "{doc}"
        );
    }
}

#[test]
fn estimator_contract_is_uniform_across_families() {
    let ds = hybrid_ds();
    let reg_ds = generate_any(&SynthSpec::regression("mreg", 400, 6), 9);

    let tree = <Tree as Estimator>::fit(&ds, &Udt::builder().build().unwrap()).unwrap();
    let forest = <Forest as Estimator>::fit(&ds, &Forest::builder().n_trees(3).build().unwrap())
        .unwrap();

    let rows: Vec<Vec<Value>> = (0..16).map(|r| ds.row(r)).collect();
    // Batch output matches row-by-row output for both families.
    assert_eq!(tree.predict_batch(&rows).unwrap().len(), 16);
    assert_eq!(forest.predict_batch(&rows).unwrap().len(), 16);
    // Evaluation returns the classification quality flavor.
    assert!(matches!(
        tree.evaluate(&ds).unwrap(),
        udt::Quality::Accuracy(_)
    ));
    assert!(matches!(
        forest.evaluate(&ds).unwrap(),
        udt::Quality::Accuracy(_)
    ));
    // Task mismatch is typed for both.
    assert!(matches!(
        tree.evaluate(&reg_ds),
        Err(UdtError::TaskMismatch { .. })
    ));
    assert!(matches!(
        forest.evaluate(&reg_ds),
        Err(UdtError::TaskMismatch { .. })
    ));
}
