//! End-to-end runtime tests: load the AOT artifacts (built by
//! `make artifacts`), execute them through PJRT, and check the numbers
//! against the native Rust engine. Skipped (with a notice) when the
//! artifacts have not been built. The whole file compiles only with the
//! `xla` cargo feature (the PJRT engine needs the external `xla` crate).
#![cfg(feature = "xla")]

use udt::data::column::Column;
use udt::data::value::Value;
use udt::runtime::engine::Engine;
use udt::runtime::xla_split::{XlaSelection, XlaSelectionConfig};
use udt::selection::heuristic::{ClassCriterion, Criterion};
use udt::selection::superfast::{best_split_on_feat, FeatureView, LabelsView, Scratch};
use udt::util::rng::Rng;

fn engine() -> Option<Engine> {
    match Engine::load_default() {
        Some(e) => Some(e),
        None => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn engine_loads_manifest_artifacts() {
    let Some(engine) = engine() else { return };
    assert_eq!(engine.platform(), "cpu");
    let names = engine.names();
    assert!(
        names.iter().any(|n| n.starts_with("split_select_m")),
        "{names:?}"
    );
    // Variant selection picks the smallest fitting M.
    let v = engine.variant_for(100, 2).unwrap();
    assert_eq!(v.spec.m, 4096);
}

#[test]
fn split_select_artifact_matches_native_scores() {
    let Some(engine) = engine() else { return };
    let artifact = engine.variant_for(1000, 3).unwrap();
    let (m, b, c) = (artifact.spec.m, artifact.spec.b, artifact.spec.c);

    // Data: 1000 rows over 7 distinct values (bins are exact), 3 classes.
    let mut rng = Rng::new(99);
    let n = 1000usize;
    let n_distinct = 7usize;
    let values: Vec<i32> = (0..n).map(|_| rng.below(n_distinct as u64) as i32).collect();
    let labels_u16: Vec<u16> = values
        .iter()
        .map(|&v| {
            if rng.chance(0.8) {
                ((v as usize) * 3 / n_distinct) as u16
            } else {
                rng.below(3) as u16
            }
        })
        .collect();

    // Kernel inputs: sorted by value, bin id = value (exact binning).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| values[i]);
    let mut bin_ids = vec![0i32; m];
    let mut label_ids = vec![0i32; m];
    let mut mask = vec![0f32; m];
    for (slot, &i) in order.iter().enumerate() {
        bin_ids[slot] = values[i];
        label_ids[slot] = labels_u16[i] as i32;
        mask[slot] = 1.0;
    }
    let rest = vec![0f32; c];
    let outputs = artifact
        .execute(&[
            xla::Literal::vec1(&bin_ids),
            xla::Literal::vec1(&label_ids),
            xla::Literal::vec1(&mask),
            xla::Literal::vec1(&rest),
        ])
        .unwrap();
    assert_eq!(outputs.len(), 2);
    let le: Vec<f32> = outputs[0].to_vec().unwrap();
    let gt: Vec<f32> = outputs[1].to_vec().unwrap();
    assert_eq!(le.len(), b);

    // Native oracle: per-candidate info gain on the same data.
    let col = Column::new(
        "f",
        values.iter().map(|&v| Value::Num(v as f64)).collect::<Vec<_>>(),
    );
    let rows: Vec<u32> = (0..n as u32).collect();
    let sorted = col.sorted_numeric();
    let view = FeatureView::new(0, &col, &rows, &sorted.0, &sorted.1);
    let lv = LabelsView::Class {
        ids: &labels_u16,
        n_classes: 3,
    };
    let native = best_split_on_feat(&view, &lv, Criterion::Class(ClassCriterion::InfoGain))
        .expect("has a split");

    // The artifact's best over (le, gt) must match the native best score
    // (exact binning ⇒ identical candidate set), up to f32 precision.
    let kernel_best = le
        .iter()
        .chain(gt.iter())
        .copied()
        .filter(|s| *s > -1e29)
        .fold(f32::NEG_INFINITY, f32::max);
    assert!(
        (kernel_best as f64 - native.score).abs() < 1e-4,
        "kernel {kernel_best} vs native {}",
        native.score
    );
}

#[test]
fn xla_backend_agrees_with_native_on_exact_bins() {
    let Some(_) = engine() else { return };
    let xla_sel = XlaSelection::load_default(XlaSelectionConfig { min_rows: 1 }).unwrap();

    let mut rng = Rng::new(7);
    let n = 2000usize;
    // ≤ 256 distinct values → binning exact; hybrid column with cats+missing.
    let mut interner = udt::data::interner::Interner::new();
    let cats: Vec<_> = (0..3).map(|i| interner.intern(&format!("k{i}"))).collect();
    let vals: Vec<Value> = (0..n)
        .map(|_| {
            let r = rng.f64();
            if r < 0.1 {
                Value::Missing
            } else if r < 0.3 {
                Value::Cat(*rng.choose(&cats))
            } else {
                Value::Num(rng.below(200) as f64)
            }
        })
        .collect();
    let labels: Vec<u16> = vals
        .iter()
        .map(|v| match v {
            Value::Num(x) if *x < 60.0 => 0,
            Value::Num(_) => 1,
            _ => rng.below(2) as u16,
        })
        .collect();
    let col = Column::new("f", vals);
    let rows: Vec<u32> = (0..n as u32).collect();
    let sorted = col.sorted_numeric();
    let view = FeatureView::new(0, &col, &rows, &sorted.0, &sorted.1);
    let lv = LabelsView::Class {
        ids: &labels,
        n_classes: 2,
    };
    let crit = Criterion::Class(ClassCriterion::InfoGain);
    let mut scratch = Scratch::new();

    let native = best_split_on_feat(&view, &lv, crit).unwrap();
    let accel = xla_sel
        .best_split_on_feat(&view, &lv, crit, &mut scratch)
        .unwrap();
    assert!(
        (native.score - accel.score).abs() < 1e-4,
        "native {} vs xla {}",
        native.score,
        accel.score
    );
    assert_eq!(native.op, accel.op);
}

#[test]
fn tree_fit_with_xla_backend_learns() {
    let Some(_) = engine() else { return };
    let xla_sel = XlaSelection::load_default(XlaSelectionConfig { min_rows: 256 }).unwrap();
    let mut spec = udt::data::synth::SynthSpec::classification("xla_t", 3000, 5, 2);
    spec.numeric_cardinality = 128; // exact binning throughout
    let ds = udt::data::synth::generate_classification(&spec, 5);
    let cfg = udt::tree::TrainConfig {
        backend: udt::tree::Backend::Xla(std::sync::Arc::new(xla_sel)),
        ..Default::default()
    };
    let tree = udt::Tree::fit(&ds, &cfg).unwrap();
    let acc = tree.accuracy(&ds).unwrap();
    assert!(acc > 0.9, "accuracy {acc}");
}

#[test]
fn label_split_artifact_matches_algorithm6() {
    let Some(engine) = engine() else { return };
    let Ok(artifact) = engine.get("label_split_m4096") else {
        eprintln!("SKIP: label_split artifact not present");
        return;
    };
    let m = artifact.spec.m;
    let mut rng = Rng::new(3);
    let n = 500usize;
    let mut targets: Vec<f64> = (0..n).map(|_| (rng.below(40) as f64) * 0.5).collect();
    targets.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mut values = vec![0f32; m];
    let mut mask = vec![0f32; m];
    for i in 0..n {
        values[i] = targets[i] as f32;
        mask[i] = 1.0;
    }
    // Padding mirrors aot: repeat the last value with mask 0.
    for i in n..m {
        values[i] = targets[n - 1] as f32;
    }
    let outputs = artifact
        .execute(&[xla::Literal::vec1(&values), xla::Literal::vec1(&mask)])
        .unwrap();
    let scores: Vec<f32> = outputs[0].to_vec().unwrap();

    // Native Algorithm 6.
    let sorted_rows: Vec<u32> = (0..n as u32).collect();
    let (native_t, native_s) =
        udt::tree::label_split::best_label_split(&sorted_rows, &targets).unwrap();

    let (best_i, best_s) = scores
        .iter()
        .enumerate()
        .filter(|(_, s)| **s > -1e29)
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    assert!(
        (*best_s as f64 - native_s).abs() < native_s.abs() * 1e-4 + 1e-2,
        "kernel {best_s} vs native {native_s}"
    );
    assert_eq!(values[best_i] as f64, native_t);
}
