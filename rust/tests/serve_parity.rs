//! Cross-backend protocol parity and stress suite for the prediction
//! server: the reactor backend must answer byte-for-byte what the
//! thread-per-connection oracle answers, across the full wire protocol
//! and under hostile conditions — pipelined segments, reads split
//! mid-UTF-8, malformed JSON, oversized lines, over-budget connects and
//! peers that refuse to drain their socket.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};
use udt::coordinator::reactor;
use udt::coordinator::serve::{ServeBackend, ServeConfig, Server};
use udt::data::synth::{generate_classification, SynthSpec};
use udt::util::json::Json;
use udt::{Model, SavedModel, Udt};

/// Every backend that exists on this platform (threads always; reactor
/// on Linux).
fn backends() -> Vec<ServeBackend> {
    if reactor::SUPPORTED {
        vec![ServeBackend::Threads, ServeBackend::Reactor]
    } else {
        vec![ServeBackend::Threads]
    }
}

/// One model document for the whole suite: trained once, then
/// rehydrated per server, so every server (and the in-process oracle)
/// holds a bit-identical model and responses can be compared as bytes.
fn saved_model() -> SavedModel {
    static DOC: std::sync::OnceLock<String> = std::sync::OnceLock::new();
    let text = DOC.get_or_init(|| {
        let mut spec = SynthSpec::classification("parity", 600, 4, 3);
        spec.cat_frac = 0.3;
        let ds = generate_classification(&spec, 4242);
        let tree = Udt::builder().fit(&ds).unwrap();
        SavedModel::new(Model::SingleTree(tree), &ds)
            .to_json()
            .to_string()
    });
    SavedModel::from_json(&Json::parse(text).unwrap()).unwrap()
}

struct Live {
    server: Arc<Server>,
    addr: SocketAddr,
    handle: std::thread::JoinHandle<()>,
}

impl Live {
    fn start(cfg: ServeConfig) -> Live {
        let server = Server::new(saved_model()).unwrap();
        let (tx, rx) = mpsc::channel();
        let s2 = Arc::clone(&server);
        let handle = std::thread::spawn(move || {
            s2.serve_with(cfg, "127.0.0.1:0", |addr| tx.send(addr).unwrap())
                .unwrap();
        });
        let addr = rx.recv().unwrap();
        Live {
            server,
            addr,
            handle,
        }
    }

    fn connect(&self) -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(self.addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        (stream, reader)
    }

    /// Shut down via the protocol and join the serve thread.
    fn stop(self) {
        let (mut stream, mut reader) = self.connect();
        stream.write_all(b"\"shutdown\"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "\"bye\"");
        self.handle.join().unwrap();
    }
}

fn request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> String {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert!(resp.ends_with('\n'), "truncated response to {line:?}");
    resp.trim_end_matches('\n').to_string()
}

/// The full protocol surface (minus `stats`, whose counters depend on
/// request history): control lines, single rows, batches, named models,
/// schema addressing, and the whole error taxonomy.
const PROTOCOL_LINES: &[&str] = &[
    "ping",
    "\"ping\"",
    "schema",
    "models",
    "[1.0, 2.0, 3.0, 4.0]",
    "[\"never-seen\", 2.0, null, 4.0]",
    "[[1.0, 2.0, 3.0, 4.0], [4.0, 3.0, 2.0, 1.0]]",
    "{\"model\":\"default\",\"rows\":[[1.0, 2.0, 3.0, 4.0]]}",
    "{\"model\":\"default\",\"rows\":[1.0, 2.0, 3.0, 4.0]}",
    "{\"model\":\"default\",\"rows\":[]}",
    "{\"schema\":\"default\"}",
    "{\"schema\":\"gone\"}",
    "{\"model\":\"nope\",\"rows\":[[1.0, 2.0, 3.0, 4.0]]}",
    "{\"rows\":[[1.0, 2.0, 3.0, 4.0]]}",
    "{\"model\":7,\"rows\":[[1.0]]}",
    "{\"no_rows\":true}",
    "[1.0]",
    "[1.0, 2.0,",
    "hello",
    "42",
];

#[test]
fn backends_answer_the_full_protocol_byte_identically() {
    // The in-process handler is the ground truth both backends must
    // reproduce over the wire. Models train deterministically, so the
    // oracle transcript is identical across the per-backend servers.
    let oracle = Server::new(saved_model()).unwrap();
    for backend in backends() {
        let live = Live::start(ServeConfig {
            backend,
            ..Default::default()
        });
        let (mut stream, mut reader) = live.connect();
        for line in PROTOCOL_LINES {
            let wire = request(&mut stream, &mut reader, line);
            assert_eq!(
                wire,
                oracle.handle(line),
                "{} backend diverges on {line:?}",
                backend.name()
            );
        }
        live.stop();
    }
}

#[test]
fn pipelined_requests_in_one_segment_answer_in_order() {
    for backend in backends() {
        let live = Live::start(ServeConfig {
            backend,
            ..Default::default()
        });
        let (mut stream, mut reader) = live.connect();
        // One write_all, three requests — responses must come back in
        // request order, one line each.
        stream
            .write_all(b"ping\n[1.0, 2.0, 3.0, 4.0]\nmodels\n")
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "\"pong\"", "{}", backend.name());
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(!line.contains("error"), "{}: {line}", backend.name());
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"models\""), "{}: {line}", backend.name());
        live.stop();
    }
}

#[test]
fn requests_split_mid_utf8_survive_read_boundaries() {
    // "é" is 0xC3 0xA9; splitting between the two bytes lands a read
    // boundary (and, on the threads backend, at least one 50 ms timeout
    // tick) inside a UTF-8 sequence. A backend that converted partial
    // buffers to text eagerly would corrupt or drop the category.
    let line = "[\"caf\u{e9}-cat\", 2.0, 3.0, 4.0]";
    let bytes = line.as_bytes();
    let cut = line.find('\u{e9}').unwrap() + 1; // inside the é sequence
    for backend in backends() {
        let live = Live::start(ServeConfig {
            backend,
            ..Default::default()
        });
        let (mut stream, mut reader) = live.connect();
        let whole = request(&mut stream, &mut reader, line);

        stream.write_all(&bytes[..cut]).unwrap();
        stream.flush().unwrap();
        std::thread::sleep(Duration::from_millis(120));
        stream.write_all(&bytes[cut..]).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut split = String::new();
        reader.read_line(&mut split).unwrap();
        assert_eq!(
            split.trim_end_matches('\n'),
            whole,
            "{} backend corrupts split reads",
            backend.name()
        );
        live.stop();
    }
}

#[test]
fn trailing_unterminated_line_is_answered_at_eof() {
    for backend in backends() {
        let live = Live::start(ServeConfig {
            backend,
            ..Default::default()
        });
        let (mut stream, mut reader) = live.connect();
        stream.write_all(b"ping").unwrap(); // no newline
        stream.shutdown(Shutdown::Write).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "\"pong\"", "{}", backend.name());
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "{}", backend.name());
        live.stop();
    }
}

#[test]
fn oversized_lines_get_a_typed_error_then_close() {
    for backend in backends() {
        let live = Live::start(ServeConfig {
            backend,
            max_request_bytes: 64,
            ..Default::default()
        });
        // A terminated line over the cap.
        let (mut stream, mut reader) = live.connect();
        let big = format!("[{}]\n", "1.0, ".repeat(40));
        assert!(big.len() > 65);
        stream.write_all(big.as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let err = Json::parse(&line).unwrap();
        let msg = err.get("error").unwrap().as_str().unwrap().to_string();
        assert!(
            msg.contains("max_request_bytes") && msg.contains("64"),
            "{}: {msg}",
            backend.name()
        );
        // ... and the connection is closed.
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "{}", backend.name());

        // A never-terminated flood over the cap must not buffer forever:
        // the partial line alone triggers the same typed error + close.
        let (mut stream, mut reader) = live.connect();
        stream.write_all(&[b'x'; 200]).unwrap();
        stream.flush().unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("max_request_bytes"), "{}: {line}", backend.name());
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "{}", backend.name());

        // The server survives both abusive clients.
        let (mut stream, mut reader) = live.connect();
        assert_eq!(request(&mut stream, &mut reader, "ping"), "\"pong\"");
        live.stop();
    }
}

#[test]
fn over_budget_connections_are_rejected_then_recover() {
    for backend in backends() {
        let live = Live::start(ServeConfig {
            backend,
            max_connections: 2,
            ..Default::default()
        });
        // Fill the budget, with a round-trip on each so the server has
        // definitely registered both (connect() succeeding only proves
        // the kernel finished the handshake, not that accept() ran).
        let (mut c1, mut r1) = live.connect();
        assert_eq!(request(&mut c1, &mut r1, "ping"), "\"pong\"");
        let (mut c2, mut r2) = live.connect();
        assert_eq!(request(&mut c2, &mut r2, "ping"), "\"pong\"");

        // Third connect: typed rejection line, then close.
        let (_c3, mut r3) = live.connect();
        let mut line = String::new();
        r3.read_line(&mut line).unwrap();
        let err = Json::parse(&line).unwrap();
        let msg = err.get("error").unwrap().as_str().unwrap().to_string();
        assert!(
            msg.contains("connection budget") && msg.contains("2"),
            "{}: {msg}",
            backend.name()
        );
        line.clear();
        assert_eq!(r3.read_line(&mut line).unwrap(), 0, "{}", backend.name());

        // Freeing a slot lets the next connect through.
        drop(c2);
        drop(r2);
        std::thread::sleep(Duration::from_millis(150));
        let (mut c4, mut r4) = live.connect();
        assert_eq!(
            request(&mut c4, &mut r4, "ping"),
            "\"pong\"",
            "{} backend did not recover a freed slot",
            backend.name()
        );

        let stats = Json::parse(&request(&mut c4, &mut r4, "stats")).unwrap();
        let srv = stats.get("server").unwrap();
        assert!(srv.get("rejected").unwrap().as_f64().unwrap() >= 1.0);

        // Free both slots before stop(), which needs a connection of its
        // own to issue the protocol shutdown.
        drop((c1, r1, c4, r4));
        std::thread::sleep(Duration::from_millis(150));
        live.stop();
    }
}

#[test]
fn a_slow_reader_does_not_stall_other_clients() {
    for backend in backends() {
        let live = Live::start(ServeConfig {
            backend,
            ..Default::default()
        });
        // The slow reader requests a real batch and then never reads.
        let (mut slow, _slow_reader) = live.connect();
        let row = "[1.0, 2.0, 3.0, 4.0]";
        let batch = format!("[{}]\n", vec![row; 500].join(", "));
        slow.write_all(batch.as_bytes()).unwrap();

        // A well-behaved client must still get sub-second answers.
        let (mut fast, mut fast_reader) = live.connect();
        for _ in 0..5 {
            let t = Instant::now();
            assert_eq!(request(&mut fast, &mut fast_reader, "ping"), "\"pong\"");
            assert!(
                t.elapsed() < Duration::from_secs(1),
                "{} backend stalled behind a slow reader",
                backend.name()
            );
        }
        live.stop();
    }
}

#[test]
fn reactor_closes_abusive_peers_at_the_write_buffer_cap() {
    if !reactor::SUPPORTED {
        return;
    }
    let live = Live::start(ServeConfig {
        backend: ServeBackend::Reactor,
        // Tiny cap so the test fills kernel buffers + user buffer fast.
        max_write_buffer_bytes: 64 * 1024,
        ..Default::default()
    });
    let (mut abusive, _abusive_reader) = live.connect();
    abusive
        .set_write_timeout(Some(Duration::from_secs(2)))
        .unwrap();
    let row = "[1.0, 2.0, 3.0, 4.0]";
    let batch = format!("[{}]\n", vec![row; 2000].join(", "));
    // Pipeline batches without ever reading. Kernel buffers absorb the
    // first responses; once they fill, the server's write buffer grows
    // past the cap and the reactor closes us — our writes then fail.
    let mut server_closed_us = false;
    for _ in 0..1000 {
        match abusive.write_all(batch.as_bytes()) {
            Ok(()) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::BrokenPipe
                        | ErrorKind::ConnectionReset
                        | ErrorKind::WouldBlock
                        | ErrorKind::TimedOut
                ) =>
            {
                server_closed_us = true;
                break;
            }
            Err(e) => panic!("unexpected write error: {e}"),
        }
    }
    assert!(
        server_closed_us,
        "reactor never applied the write-buffer cap"
    );

    // The reactor itself is fine, and it observed the stall.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let (mut c, mut r) = live.connect();
        let stats = Json::parse(&request(&mut c, &mut r, "stats")).unwrap();
        let srv = stats.get("server").unwrap();
        let stalls = srv.get("backpressure_stalls").unwrap().as_f64().unwrap();
        let closed = srv.get("closed").unwrap().as_f64().unwrap();
        if stalls >= 1.0 && closed >= 1.0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "stats never recorded the backpressure close: {stats:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    live.stop();
}

#[test]
fn stats_report_connection_counters_per_server() {
    for backend in backends() {
        let live = Live::start(ServeConfig {
            backend,
            ..Default::default()
        });
        // A crowd of idle connections, round-tripped so they're all
        // registered before the stats snapshot.
        let idle: Vec<_> = (0..50)
            .map(|_| {
                let (mut s, mut r) = live.connect();
                assert_eq!(request(&mut s, &mut r, "ping"), "\"pong\"");
                (s, r)
            })
            .collect();
        let (mut c, mut r) = live.connect();
        let stats = Json::parse(&request(&mut c, &mut r, "stats")).unwrap();
        let srv = stats.get("server").unwrap();
        assert_eq!(
            srv.get("backend").unwrap().as_str().unwrap(),
            backend.name()
        );
        let active = srv.get("active_connections").unwrap().as_f64().unwrap();
        assert_eq!(active, 51.0, "{}", backend.name());
        assert!(srv.get("peak_connections").unwrap().as_f64().unwrap() >= 51.0);
        assert!(srv.get("accepted").unwrap().as_f64().unwrap() >= 51.0);
        assert!(srv.get("bytes_in").unwrap().as_f64().unwrap() >= 51.0 * 5.0);
        assert!(srv.get("bytes_out").unwrap().as_f64().unwrap() >= 51.0 * 7.0);
        for key in ["rejected", "closed", "backpressure_stalls"] {
            assert!(srv.get(key).is_some(), "{} missing {key}", backend.name());
        }
        drop(idle);
        live.stop();
    }
}

#[test]
fn shutdown_disconnects_idle_clients_promptly() {
    // The serve-side latency assertion lives in the serve.rs unit tests;
    // this covers the client's view: an idle connection sees EOF (not a
    // hang) once another client shuts the server down.
    for backend in backends() {
        let live = Live::start(ServeConfig {
            backend,
            ..Default::default()
        });
        let (idle, mut idle_reader) = live.connect();
        let (mut s, mut r) = live.connect();
        assert_eq!(request(&mut s, &mut r, "ping"), "\"pong\"");
        live.stop();
        let mut buf = [0u8; 16];
        let n = idle_reader.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "{} backend left idle client dangling", backend.name());
        drop(idle);
    }
}
