//! Property tests for the histogram-binned backend: on random hybrid
//! (numeric/categorical/missing) datasets whose per-column distinct
//! numeric counts fit inside the bin budget, binned selection must
//! produce **node-for-node identical** trees to the exact Superfast
//! engine, at 1 and N threads — and when the budget genuinely coarsens
//! the threshold set, the tree must stay thread-count invariant and the
//! accuracy loss bounded. Forest bags over the binned backend must share
//! a single dataset-level quantization.

use udt::data::synth::{generate_any, SynthSpec};
use udt::tree::forest::{Forest, ForestConfig};
use udt::tree::{Backend, TrainConfig, Tree};
use udt::util::prop::{check, ensure, Config};
use udt::util::rng::Rng;

/// Random hybrid classification spec whose numeric grids stay at or
/// below 32 distinct levels, so a bin budget of 64 is always lossless.
fn random_exactable_spec(rng: &mut Rng, size: usize) -> SynthSpec {
    let n_rows = rng.range(60, size.max(80));
    let n_features = rng.range(2, 7);
    let mut spec = SynthSpec::classification("pbin", n_rows, n_features, rng.range(2, 5));
    spec.cat_frac = rng.f64() * 0.5;
    spec.hybrid_frac = rng.f64() * 0.3;
    spec.missing_frac = rng.f64() * 0.15;
    spec.numeric_cardinality = rng.range(2, 33);
    spec.gt_depth = rng.range(2, 7);
    spec.noise = rng.f64() * 0.2;
    spec
}

/// Node-for-node structural equality (splits, children, samples, labels).
fn same_tree(a: &Tree, b: &Tree) -> Result<(), String> {
    ensure(
        a.n_nodes() == b.n_nodes(),
        format!("node counts differ: {} vs {}", a.n_nodes(), b.n_nodes()),
    )?;
    ensure(
        a.depth == b.depth,
        format!("depths differ: {} vs {}", a.depth, b.depth),
    )?;
    for (i, (x, y)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        ensure(
            x.split == y.split,
            format!("node {i} split: {:?} vs {:?}", x.split, y.split),
        )?;
        ensure(
            x.children == y.children,
            format!("node {i} children: {:?} vs {:?}", x.children, y.children),
        )?;
        ensure(
            x.n_samples == y.n_samples,
            format!("node {i} samples: {} vs {}", x.n_samples, y.n_samples),
        )?;
        ensure(
            x.label == y.label,
            format!("node {i} label: {:?} vs {:?}", x.label, y.label),
        )?;
    }
    Ok(())
}

#[test]
fn binned_matches_exact_when_bins_cover_the_distincts() {
    check(
        "binned ≡ superfast on lossless lanes (1 and 4 threads)",
        Config::default().cases(25).max_size(300).seed(0xB144_ED01),
        |rng, size| {
            let spec = random_exactable_spec(rng, size);
            let ds = generate_any(&spec, rng.next_u64());
            let exact = Tree::fit(&ds, &TrainConfig::default()).map_err(|e| e.to_string())?;
            for n_threads in [1, 4] {
                let binned = Tree::fit(
                    &ds,
                    &TrainConfig {
                        backend: Backend::Binned { max_bins: 64 },
                        n_threads,
                        ..Default::default()
                    },
                )
                .map_err(|e| e.to_string())?;
                same_tree(&exact, &binned)?;
            }
            ensure(
                ds.binned_index(64).all_exact(),
                "expected lossless lanes at B=64",
            )?;
            ensure(
                ds.bin_index_builds() == 1,
                format!("bin lanes built {} times, expected 1", ds.bin_index_builds()),
            )
        },
    );
}

#[test]
fn lossy_binned_tree_is_thread_count_invariant() {
    check(
        "binned B=16 on coarsened grids: 1-thread ≡ 4-thread build",
        Config::default().cases(20).max_size(300).seed(0xB144_ED02),
        |rng, size| {
            let mut spec = random_exactable_spec(rng, size);
            // Well above the budget, so thresholds genuinely snap to
            // bin edges and the histogram path (not the small-node
            // exact fallback alone) decides real splits.
            spec.numeric_cardinality = rng.range(64, 400);
            let ds = generate_any(&spec, rng.next_u64());
            let cfg = |n_threads| TrainConfig {
                backend: Backend::Binned { max_bins: 16 },
                n_threads,
                ..Default::default()
            };
            let seq = Tree::fit(&ds, &cfg(1)).map_err(|e| e.to_string())?;
            let par = Tree::fit(&ds, &cfg(4)).map_err(|e| e.to_string())?;
            same_tree(&seq, &par)
        },
    );
}

#[test]
fn small_bin_budget_stays_within_accuracy_tolerance() {
    // B=16 over a 1000-level grid is deliberately lossy; the held-out
    // accuracy may dip but must stay close to the exact tree's.
    for seed in [3u64, 11, 29] {
        let mut spec = SynthSpec::classification("btol", 2_000, 8, 4);
        spec.numeric_cardinality = 1_000;
        spec.noise = 0.05;
        let ds = generate_any(&spec, seed);
        let (train, _val, test) = ds.split_indices(0.8, 0.1, seed);
        let exact = Tree::fit_rows(&ds, &train, &TrainConfig::default()).unwrap();
        let binned = Tree::fit_rows(
            &ds,
            &train,
            &TrainConfig {
                backend: Backend::Binned { max_bins: 16 },
                ..Default::default()
            },
        )
        .unwrap();
        let acc_exact = exact.accuracy_rows(&ds, &test).unwrap();
        let acc_binned = binned.accuracy_rows(&ds, &test).unwrap();
        assert!(
            acc_binned >= acc_exact - 0.1,
            "seed {seed}: B=16 accuracy {acc_binned} fell too far below exact {acc_exact}"
        );
    }
}

#[test]
fn forest_bags_share_one_quantization() {
    let mut spec = SynthSpec::classification("bforest", 1_500, 6, 3);
    spec.numeric_cardinality = 500;
    let ds = generate_any(&spec, 17);
    let cfg = ForestConfig {
        n_trees: 8,
        tree: TrainConfig {
            backend: Backend::Binned { max_bins: 32 },
            ..Default::default()
        },
        ..Default::default()
    };
    let forest = Forest::fit(&ds, &cfg).unwrap();
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let acc = forest.accuracy_rows(&ds, &rows).unwrap();
    assert!(acc > 0.6, "binned forest accuracy {acc}");
    // Eight bags, one sort, one quantization.
    assert_eq!(ds.sort_index_builds(), 1);
    assert_eq!(ds.bin_index_builds(), 1);
}
