//! The paper's core correctness theorem, as property tests:
//! **Superfast Selection ≡ generic selection** — same best score (and same
//! split under deterministic tie-breaking) on arbitrary hybrid data, for
//! every supported criterion, plus prefix-sum and invariance properties.

use udt::data::column::Column;
use udt::data::interner::Interner;
use udt::data::value::Value;
use udt::selection::generic::best_split_on_feat_generic;
use udt::selection::heuristic::{ClassCriterion, Criterion};
use udt::selection::superfast::{best_split_on_feat, FeatureView, LabelsView};
use udt::util::prop::{check, ensure, ensure_close, Config};
use udt::util::rng::Rng;

/// Random hybrid column + classification labels.
fn random_case(
    rng: &mut Rng,
    size: usize,
) -> (Column, Vec<u16>, usize, Interner) {
    let n = rng.range(2, size.max(3));
    let n_classes = rng.range(2, 6);
    let n_values = rng.range(1, 12); // small domain → many duplicates
    let cat_prob = rng.f64() * 0.6;
    let missing_prob = rng.f64() * 0.2;
    let mut interner = Interner::new();
    let cats: Vec<_> = (0..4).map(|i| interner.intern(&format!("c{i}"))).collect();
    let mut vals = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let r = rng.f64();
        let v = if r < missing_prob {
            Value::Missing
        } else if r < missing_prob + cat_prob {
            Value::Cat(*rng.choose(&cats))
        } else {
            // Include negative and fractional values.
            Value::Num(rng.range(0, n_values) as f64 * 1.5 - 4.0)
        };
        vals.push(v);
        labels.push(rng.below(n_classes as u64) as u16);
    }
    (Column::new("f", vals), labels, n_classes, interner)
}

fn view_of<'a>(
    col: &'a Column,
    rows: &'a [u32],
    sorted: &'a (Vec<u32>, Vec<f64>),
) -> FeatureView<'a> {
    FeatureView::new(0, col, rows, &sorted.0, &sorted.1)
}

#[test]
fn superfast_equals_generic_classification() {
    for criterion in [
        ClassCriterion::InfoGain,
        ClassCriterion::Gini,
        ClassCriterion::ChiSquare,
    ] {
        check(
            &format!("superfast ≡ generic ({})", criterion.name()),
            Config::default().cases(150).max_size(200).seed(criterion.name().len() as u64),
            |rng, size| {
                let (col, labels, n_classes, _) = random_case(rng, size);
                let rows: Vec<u32> = (0..col.len() as u32).collect();
                let sorted = col.sorted_numeric();
                let view = view_of(&col, &rows, &sorted);
                let lv = LabelsView::Class {
                    ids: &labels,
                    n_classes,
                };
                let crit = Criterion::Class(criterion);
                let fast = best_split_on_feat(&view, &lv, crit);
                let slow = best_split_on_feat_generic(&view, &lv, crit);
                match (fast, slow) {
                    (None, None) => Ok(()),
                    (Some(a), Some(b)) => {
                        ensure_close(a.score, b.score, 1e-9, "best scores differ")?;
                        ensure(
                            a.op == b.op,
                            format!("ops differ: {:?} vs {:?} (scores {} / {})", a.op, b.op, a.score, b.score),
                        )
                    }
                    (a, b) => Err(format!("one engine found a split: {a:?} vs {b:?}")),
                }
            },
        );
    }
}

#[test]
fn superfast_equals_generic_regression() {
    check(
        "superfast ≡ generic (sse)",
        Config::default().cases(120).max_size(150),
        |rng, size| {
            let (col, _, _, _) = random_case(rng, size);
            let n = col.len();
            let targets: Vec<f64> = (0..n).map(|_| rng.f64_range(-5.0, 5.0)).collect();
            let rows: Vec<u32> = (0..n as u32).collect();
            let sorted = col.sorted_numeric();
            let view = view_of(&col, &rows, &sorted);
            let lv = LabelsView::Reg { values: &targets };
            let fast = best_split_on_feat(&view, &lv, Criterion::Sse);
            let slow = best_split_on_feat_generic(&view, &lv, Criterion::Sse);
            match (fast, slow) {
                (None, None) => Ok(()),
                (Some(a), Some(b)) => {
                    ensure_close(a.score, b.score, 1e-9, "scores")?;
                    ensure(a.op == b.op, format!("ops differ: {:?} vs {:?}", a.op, b.op))
                }
                (a, b) => Err(format!("mismatch: {a:?} vs {b:?}")),
            }
        },
    );
}

#[test]
fn selection_is_row_order_invariant() {
    check(
        "row permutation does not change the best split",
        Config::default().cases(60).max_size(120),
        |rng, size| {
            let (col, labels, n_classes, _) = random_case(rng, size);
            let n = col.len();
            let rows: Vec<u32> = (0..n as u32).collect();
            let mut shuffled = rows.clone();
            rng.shuffle(&mut shuffled);
            let sorted = col.sorted_numeric();
            let lv = LabelsView::Class {
                ids: &labels,
                n_classes,
            };
            let crit = Criterion::Class(ClassCriterion::InfoGain);
            let a = best_split_on_feat(&view_of(&col, &rows, &sorted), &lv, crit);
            let b = best_split_on_feat(&view_of(&col, &shuffled, &sorted), &lv, crit);
            match (a, b) {
                (None, None) => Ok(()),
                (Some(a), Some(b)) => {
                    ensure_close(a.score, b.score, 1e-9, "permutation changed score")?;
                    ensure(a.op == b.op, "permutation changed op")
                }
                _ => Err("permutation changed existence".into()),
            }
        },
    );
}

#[test]
fn best_score_upper_bounds_every_candidate() {
    // The returned split must be at least as good as a random predicate's
    // direct evaluation.
    check(
        "best split dominates sampled candidates",
        Config::default().cases(80).max_size(100),
        |rng, size| {
            let (col, labels, n_classes, _) = random_case(rng, size);
            let rows: Vec<u32> = (0..col.len() as u32).collect();
            let sorted = col.sorted_numeric();
            let view = view_of(&col, &rows, &sorted);
            let lv = LabelsView::Class {
                ids: &labels,
                n_classes,
            };
            let crit = Criterion::Class(ClassCriterion::InfoGain);
            let Some(best) = best_split_on_feat(&view, &lv, crit) else {
                return Ok(());
            };
            // Sample candidate thresholds from the data.
            for _ in 0..8 {
                let r = rng.below(col.len() as u64) as usize;
                let op = match col.get(r) {
                    Value::Num(x) => {
                        if rng.chance(0.5) {
                            udt::selection::split::SplitOp::Le(x)
                        } else {
                            udt::selection::split::SplitOp::Gt(x)
                        }
                    }
                    Value::Cat(c) => udt::selection::split::SplitOp::Eq(c),
                    Value::Missing => continue,
                };
                let mut pos = vec![0.0f64; n_classes];
                let mut neg = vec![0.0f64; n_classes];
                for &rr in &rows {
                    let y = labels[rr as usize] as usize;
                    if op.eval(col.get(rr as usize)) {
                        pos[y] += 1.0;
                    } else {
                        neg[y] += 1.0;
                    }
                }
                if pos.iter().sum::<f64>() == 0.0 || neg.iter().sum::<f64>() == 0.0 {
                    continue;
                }
                let s = ClassCriterion::InfoGain.score(&pos, &neg);
                ensure(
                    best.score >= s - 1e-9,
                    format!("candidate {op:?} scores {s} > best {}", best.score),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prefix_sum_counts_match_direct_counts() {
    // Indirect prefix-sum identity: per-class counts from the sorted walk
    // must equal direct counting for `≤ x` at every distinct x.
    check(
        "prefix counts ≡ direct counts",
        Config::default().cases(60).max_size(80),
        |rng, size| {
            let (col, labels, n_classes, _) = random_case(rng, size);
            let (sorted, vals) = col.sorted_numeric();
            // Pick a random distinct numeric value.
            if sorted.is_empty() {
                return Ok(());
            }
            let x = vals[rng.below(sorted.len() as u64) as usize];
            let mut from_walk = vec![0u32; n_classes];
            for (&r, &v) in sorted.iter().zip(&vals) {
                if v <= x {
                    from_walk[labels[r as usize] as usize] += 1;
                }
            }
            let mut direct = vec![0u32; n_classes];
            for r in 0..col.len() {
                if let Value::Num(v) = col.get(r) {
                    if v <= x {
                        direct[labels[r] as usize] += 1;
                    }
                }
            }
            ensure(from_walk == direct, format!("{from_walk:?} vs {direct:?}"))
        },
    );
}
