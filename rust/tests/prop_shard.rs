//! Property tests for out-of-core sharded training: stream a CSV into
//! an on-disk shard directory, train with [`udt::tree::sharded`] through
//! bounded-RAM shard windows, and the tree must be **node-for-node
//! identical** to in-memory `--backend binned` training on the same
//! `max_bins` — at 1 and 4 threads, across hybrid and missing-heavy
//! columns — with the `peak_shard_window_bytes` witness staying below
//! the full in-memory dataset footprint.

use udt::data::csv::{load_csv_str, to_csv_string, CsvOptions};
use udt::data::dataset::{Labels, TaskKind};
use udt::data::shard::{shard_csv_str, write_dataset_shards};
use udt::data::synth::{generate_any, SynthSpec};
use udt::data::ShardedDataset;
use udt::tree::sharded::fit_sharded;
use udt::tree::{Backend, RegStrategy, TrainConfig, Tree};
use udt::util::prop::{check, ensure, Config};
use udt::util::rng::Rng;

/// Random hybrid classification spec whose numeric grids stay at or
/// below 32 distinct levels, so a bin budget of 64 is always lossless
/// (the regime where sharded ≡ in-memory binned is exact).
fn random_exactable_spec(rng: &mut Rng, size: usize) -> SynthSpec {
    let n_rows = rng.range(60, size.max(80));
    let n_features = rng.range(2, 7);
    let mut spec = SynthSpec::classification("pshard", n_rows, n_features, rng.range(2, 5));
    spec.cat_frac = rng.f64() * 0.5;
    spec.hybrid_frac = rng.f64() * 0.3;
    spec.missing_frac = rng.f64() * 0.15;
    spec.numeric_cardinality = rng.range(2, 33);
    spec.gt_depth = rng.range(2, 7);
    spec.noise = rng.f64() * 0.2;
    spec
}

/// Node-for-node structural equality (splits, children, samples, labels).
fn same_tree(a: &Tree, b: &Tree) -> Result<(), String> {
    ensure(
        a.n_nodes() == b.n_nodes(),
        format!("node counts differ: {} vs {}", a.n_nodes(), b.n_nodes()),
    )?;
    ensure(
        a.depth == b.depth,
        format!("depths differ: {} vs {}", a.depth, b.depth),
    )?;
    for (i, (x, y)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        ensure(
            x.split == y.split,
            format!("node {i} split: {:?} vs {:?}", x.split, y.split),
        )?;
        ensure(
            x.children == y.children,
            format!("node {i} children: {:?} vs {:?}", x.children, y.children),
        )?;
        ensure(
            x.n_samples == y.n_samples,
            format!("node {i} samples: {} vs {}", x.n_samples, y.n_samples),
        )?;
        ensure(
            x.label == y.label,
            format!("node {i} label: {:?} vs {:?}", x.label, y.label),
        )?;
    }
    Ok(())
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("udt-prop-shard-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn sharded_training_matches_in_memory_binned() {
    let dir = temp_dir("cls");
    check(
        "csv → shards → sharded fit ≡ in-memory binned (1 and 4 threads)",
        Config::default().cases(25).max_size(300).seed(0x5AAD_0001),
        |rng, size| {
            let spec = random_exactable_spec(rng, size);
            let csv = to_csv_string(&generate_any(&spec, rng.next_u64()));
            let opts = CsvOptions::default();
            let ds = load_csv_str("pshard", &csv, &opts).map_err(|e| e.to_string())?;

            // 3–5 shards, so windows genuinely cycle and at least one
            // subtraction level crosses shard boundaries.
            let rows_per_shard = (ds.n_rows() / rng.range(3, 6)).max(1);
            let _ = std::fs::remove_dir_all(&dir);
            shard_csv_str("pshard", &csv, &dir, &opts, rows_per_shard)
                .map_err(|e| e.to_string())?;
            let sds = ShardedDataset::open(&dir).map_err(|e| e.to_string())?;
            ensure(
                sds.n_shards() >= 2,
                format!("expected ≥ 2 shards, got {}", sds.n_shards()),
            )?;

            for n_threads in [1, 4] {
                let cfg = TrainConfig {
                    backend: Backend::Binned { max_bins: 64 },
                    n_threads,
                    ..Default::default()
                };
                let mem = Tree::fit(&ds, &cfg).map_err(|e| e.to_string())?;
                let (shd, stats) = fit_sharded(&sds, &cfg).map_err(|e| e.to_string())?;
                same_tree(&mem, &shd)?;
                // Bounded-RAM witnesses: some window was resident, and
                // it was strictly smaller than the full in-memory
                // dataset the equivalent binned fit holds.
                ensure(
                    stats.peak_shard_window_bytes > 0,
                    "peak_shard_window_bytes is 0",
                )?;
                ensure(
                    stats.peak_shard_window_bytes < ds.approx_bytes(),
                    format!(
                        "window {} B did not undercut the dataset's {} B",
                        stats.peak_shard_window_bytes,
                        ds.approx_bytes()
                    ),
                )?;
                ensure(
                    stats.shard_passes >= 3,
                    format!("expected ≥ 3 shard passes, got {}", stats.shard_passes),
                )?;
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sharded_regression_matches_in_memory_binned() {
    let dir = temp_dir("reg");
    check(
        "sharded regression ≡ in-memory binned DirectSse (1 and 4 threads)",
        Config::default().cases(15).max_size(240).seed(0x5AAD_0002),
        |rng, size| {
            let n_rows = rng.range(60, size.max(80));
            let mut spec = SynthSpec::regression("pshard-r", n_rows, rng.range(2, 6));
            spec.cat_frac = rng.f64() * 0.4;
            spec.missing_frac = rng.f64() * 0.1;
            spec.numeric_cardinality = rng.range(2, 33);
            let mut ds = generate_any(&spec, rng.next_u64());
            // Quarter-round the targets so every histogram sum is a
            // dyadic rational: accumulation order (sorted in-memory vs
            // ascending-row sharded) cannot perturb a single bit.
            if let Labels::Reg { values } = &mut ds.labels {
                for v in values.iter_mut() {
                    *v = (*v * 4.0).round() / 4.0;
                }
            }
            let rows_per_shard = (ds.n_rows() / rng.range(3, 6)).max(1);
            let _ = std::fs::remove_dir_all(&dir);
            write_dataset_shards(&ds, &dir, rows_per_shard).map_err(|e| e.to_string())?;
            let sds = ShardedDataset::open(&dir).map_err(|e| e.to_string())?;
            ensure(
                sds.task() == TaskKind::Regression,
                "manifest lost the regression task",
            )?;

            for n_threads in [1, 4] {
                let cfg = TrainConfig {
                    backend: Backend::Binned { max_bins: 64 },
                    reg_strategy: RegStrategy::DirectSse,
                    n_threads,
                    ..Default::default()
                };
                let mem = Tree::fit(&ds, &cfg).map_err(|e| e.to_string())?;
                let (shd, stats) = fit_sharded(&sds, &cfg).map_err(|e| e.to_string())?;
                same_tree(&mem, &shd)?;
                ensure(
                    stats.peak_shard_window_bytes > 0
                        && stats.peak_shard_window_bytes < ds.approx_bytes(),
                    format!(
                        "window witness {} B out of range (dataset {} B)",
                        stats.peak_shard_window_bytes,
                        ds.approx_bytes()
                    ),
                )?;
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shard_directory_round_trips_csv_schema() {
    // One deterministic mixed dataset end-to-end: the shard manifest
    // must reproduce the parsed CSV's schema exactly.
    let mut spec = SynthSpec::classification("pshard-s", 200, 5, 3);
    spec.cat_frac = 0.4;
    spec.hybrid_frac = 0.3;
    spec.missing_frac = 0.1;
    spec.numeric_cardinality = 16;
    let csv = to_csv_string(&generate_any(&spec, 7));
    let opts = CsvOptions::default();
    let ds = load_csv_str("pshard-s", &csv, &opts).unwrap();
    let dir = temp_dir("schema");
    shard_csv_str("pshard-s", &csv, &dir, &opts, 64).unwrap();
    let sds = ShardedDataset::open(&dir).unwrap();
    assert_eq!(sds.n_rows(), ds.n_rows());
    assert_eq!(sds.n_features(), ds.n_features());
    assert_eq!(
        sds.manifest().feature_names,
        ds.columns.iter().map(|c| c.name.clone()).collect::<Vec<_>>()
    );
    assert_eq!(sds.manifest().class_names, *ds.class_names);
    let _ = std::fs::remove_dir_all(&dir);
}
