//! Stress suite for the pool's dynamic race witness
//! (`runtime/pool.rs::check`): the shadow-ownership tags must catch
//! every protocol violation we can inject, and seeded yield-injection
//! at the claim/take/commit/pickup/retire/submit points must never
//! change what a batch computes — only how its schedule interleaves.
//!
//! Detection tests are gated like the witness itself
//! (`debug_assertions` or `--cfg udt_check`): plain release builds
//! compile the witness down to no-op stubs (that is the point of the
//! gate), so there is nothing to detect there. The equivalence tests
//! run in every profile; the CI sanitizer lanes run the whole file in
//! an optimized build with the witness armed via `--cfg udt_check`.

#[cfg(any(debug_assertions, udt_check))]
use std::panic::{catch_unwind, AssertUnwindSafe};
#[cfg(any(debug_assertions, udt_check))]
use std::sync::Arc;

use udt::runtime::pool::{map_scratch, witness};

/// Every detection test opts into catchable panics (the production
/// path aborts, which is untestable in-process). The flag is global
/// and sticky; legit runs never trip a violation, so leaving it set
/// is harmless to concurrently running tests.
#[cfg(any(debug_assertions, udt_check))]
fn arm() {
    witness::set_panic_on_violation(true);
}

#[cfg(any(debug_assertions, udt_check))]
fn violation_message(r: Result<(), Box<dyn std::any::Any + Send>>) -> String {
    let payload = r.expect_err("expected a witness violation");
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default()
}

#[cfg(any(debug_assertions, udt_check))]
#[test]
fn double_claim_is_caught() {
    arm();
    let tags = witness::SlotTags::new(4);
    tags.claim(1);
    let msg = violation_message(catch_unwind(AssertUnwindSafe(|| tags.claim(1))));
    assert!(
        msg.contains("double-claimed"),
        "wrong diagnostic for a double-claim: {msg}"
    );
}

#[cfg(any(debug_assertions, udt_check))]
#[test]
fn commit_without_claim_is_caught() {
    arm();
    let tags = witness::SlotTags::new(4);
    let msg = violation_message(catch_unwind(AssertUnwindSafe(|| tags.commit(2))));
    assert!(
        msg.contains("without ownership"),
        "wrong diagnostic for an unowned commit: {msg}"
    );
}

#[cfg(any(debug_assertions, udt_check))]
#[test]
fn retire_before_commit_is_caught() {
    arm();
    let tags = witness::SlotTags::new(4);
    tags.claim(0); // claimed but never committed
    let msg = violation_message(catch_unwind(AssertUnwindSafe(|| tags.assert_done(0))));
    assert!(
        msg.contains("expected DONE"),
        "wrong diagnostic for retire-before-drain: {msg}"
    );
}

#[cfg(any(debug_assertions, udt_check))]
#[test]
fn clean_protocol_run_raises_nothing() {
    arm();
    let tags = witness::SlotTags::new(8);
    for i in 0..8 {
        tags.claim(i);
        tags.commit(i);
    }
    for i in 0..8 {
        tags.assert_done(i);
    }
}

/// Racing CAS stress: four threads fight over one slot; the witness
/// must admit exactly one winner per round and fault the rest, no
/// matter how the scheduler lands.
#[cfg(any(debug_assertions, udt_check))]
#[test]
fn concurrent_double_claim_admits_exactly_one_winner() {
    arm();
    for _round in 0..8 {
        let tags = Arc::new(witness::SlotTags::new(1));
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let tags = Arc::clone(&tags);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    tags.claim(0); // panics for every thread but one
                })
            })
            .collect();
        let winners = handles
            .into_iter()
            .map(|h| h.join())
            .filter(Result::is_ok)
            .count();
        assert_eq!(winners, 1, "slot claimed by {winners} threads in one round");
        tags.commit(0);
        tags.assert_done(0);
    }
}

// ------------------------------------------------ yield-injection 1≡N

/// Node-for-node structural tree equality, matching the property
/// suites' notion of "identical".
fn same_tree(a: &udt::tree::Tree, b: &udt::tree::Tree) {
    assert_eq!(a.n_nodes(), b.n_nodes(), "node counts differ");
    assert_eq!(a.depth, b.depth, "depths differ");
    for (i, (x, y)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        assert_eq!(x.split, y.split, "node {i} split");
        assert_eq!(x.children, y.children, "node {i} children");
        assert_eq!(x.n_samples, y.n_samples, "node {i} samples");
        assert_eq!(x.label, y.label, "node {i} label");
    }
}

#[test]
fn map_scratch_is_order_and_value_exact_under_yield_injection() {
    for seed in [1u64, 42, 0xDEAD_BEEF_DEAD_BEEF] {
        witness::set_yield_seed(seed);
        let out = map_scratch(
            (0..500u64).collect::<Vec<_>>(),
            4,
            || 0u64,
            |x, calls| {
                *calls += 1;
                x * 3 + 1
            },
        );
        witness::set_yield_seed(0);
        let want: Vec<u64> = (0..500).map(|x| x * 3 + 1).collect();
        assert_eq!(out, want, "seed {seed:#x} perturbed batch results");
    }
}

#[test]
fn tree_build_is_identical_at_1_and_4_threads_under_yield_injection() {
    use udt::data::synth::{generate_any, SynthSpec};
    use udt::tree::{TrainConfig, Tree};

    let mut spec = SynthSpec::classification("race-witness", 600, 6, 3);
    spec.cat_frac = 0.3;
    spec.missing_frac = 0.1;
    spec.noise = 0.15;
    let ds = generate_any(&spec, 0xA11CE);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();

    let seq = Tree::fit_rows(&ds, &rows, &TrainConfig::default()).expect("sequential fit");
    for seed in [7u64, 0xBAD_5EED] {
        witness::set_yield_seed(seed);
        let par = Tree::fit_rows(
            &ds,
            &rows,
            &TrainConfig {
                n_threads: 4,
                ..Default::default()
            },
        )
        .expect("parallel fit under yield injection");
        witness::set_yield_seed(0);
        same_tree(&seq, &par);
    }
}
