//! Seeded-violation fixtures for the `udt-analyze` source lint
//! (`src/analysis/`): each rule gets a fixture that must trip it at an
//! exact line, a fixture that must NOT trip it (exemption or waiver),
//! and the whole suite closes with a self-scan of this very crate that
//! must come back clean — the lint gates CI, so the repo must always
//! pass its own lint.
//!
//! Fixture sources are plain string literals. The lexer masks string
//! contents before the rules run, which is exactly why this file can
//! hold `.unwrap()` / `unsafe` / waiver text in fixtures without
//! tripping the self-scan.

use udt::analysis::{analyze_source, analyze_tree};

/// Assert the fixture produces exactly the `(rule, line)` pairs given.
fn expect(rel_path: &str, src: &str, want: &[(&str, usize)]) {
    let got: Vec<(String, usize)> = analyze_source(rel_path, src)
        .findings
        .iter()
        .map(|f| (f.rule.id().to_string(), f.line))
        .collect();
    let want: Vec<(String, usize)> = want
        .iter()
        .map(|(r, l)| (r.to_string(), *l))
        .collect();
    assert_eq!(got, want, "findings for fixture at {rel_path}:\n{src}");
}

// ---------------------------------------------------------------- SAFETY

#[test]
fn unsafe_without_safety_comment_is_flagged_at_its_line() {
    let src = "pub fn f(p: *const u8) -> u8 {\n    unsafe { p.read() }\n}\n";
    expect("src/foo.rs", src, &[("safety-comment", 2)]);
}

#[test]
fn safety_comment_directly_above_satisfies_the_rule() {
    let src = "pub fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid\n    unsafe { p.read() }\n}\n";
    expect("src/foo.rs", src, &[]);
}

#[test]
fn safety_comment_reaches_through_attributes_and_blank_lines() {
    let src = "/// Docs.\n///\n/// # Safety\n/// caller upholds the contract\n#[inline]\n#[must_use]\npub unsafe fn f() {}\n";
    expect("src/foo.rs", src, &[]);
}

#[test]
fn code_line_between_safety_comment_and_unsafe_breaks_coverage() {
    let src = "// SAFETY: stale comment\nfn other() {}\nfn f(p: *const u8) {\n    unsafe { p.read() };\n}\n";
    expect("src/foo.rs", src, &[("safety-comment", 4)]);
}

#[test]
fn safety_rule_applies_even_in_test_and_bench_paths() {
    let src = "fn f(p: *const u8) -> u8 {\n    unsafe { p.read() }\n}\n";
    expect("tests/foo.rs", src, &[("safety-comment", 2)]);
    expect("benches/foo.rs", src, &[("safety-comment", 2)]);
}

// ---------------------------------------------------------- thread-spawn

#[test]
fn thread_spawn_outside_the_pool_is_flagged() {
    let src = "pub fn go() {\n    std::thread::spawn(|| {});\n}\n";
    expect("src/coordinator/foo.rs", src, &[("thread-spawn", 2)]);
}

#[test]
fn thread_scope_is_also_flagged() {
    let src = "pub fn go() {\n    std::thread::scope(|_s| {});\n}\n";
    expect("src/foo.rs", src, &[("thread-spawn", 2)]);
}

#[test]
fn the_pool_module_itself_may_spawn() {
    let src = "pub fn go() {\n    std::thread::spawn(|| {});\n}\n";
    expect("src/runtime/pool.rs", src, &[]);
}

#[test]
fn tests_and_benches_may_spawn() {
    let src = "fn go() {\n    std::thread::spawn(|| {});\n}\n";
    expect("tests/foo.rs", src, &[]);
    expect("benches/foo.rs", src, &[]);
}

// ------------------------------------------------------------- no-unwrap

#[test]
fn unwrap_expect_and_panic_are_flagged_in_library_code() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    let a = x.unwrap();\n    let b = x.expect(\"b\");\n    if a != b { panic!(\"boom\") }\n    a\n}\n";
    expect(
        "src/foo.rs",
        src,
        &[("no-unwrap", 2), ("no-unwrap", 3), ("no-unwrap", 4)],
    );
}

#[test]
fn main_rs_is_exempt_from_no_unwrap() {
    let src = "fn main() {\n    run().unwrap();\n}\n";
    expect("src/main.rs", src, &[]);
}

#[test]
fn cfg_test_modules_are_exempt_from_no_unwrap() {
    let src = "pub fn lib() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        Some(1).unwrap();\n    }\n}\n";
    expect("src/foo.rs", src, &[]);
}

#[test]
fn unwrap_before_a_cfg_test_module_is_still_flagged() {
    let src = "pub fn lib(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n\n#[cfg(test)]\nmod tests {}\n";
    expect("src/foo.rs", src, &[("no-unwrap", 2)]);
}

// --------------------------------------------------------- as-truncation

#[test]
fn narrowing_as_casts_are_flagged_in_decoder_files() {
    let src = "pub fn f(x: u64) -> u16 {\n    x as u16\n}\n";
    expect("src/data/shard/format.rs", src, &[("as-truncation", 2)]);
    expect("src/coordinator/reactor/sys.rs", src, &[("as-truncation", 2)]);
}

#[test]
fn as_casts_outside_decoder_files_are_not_this_rules_business() {
    let src = "pub fn f(x: u64) -> u16 {\n    x as u16\n}\n";
    expect("src/foo.rs", src, &[]);
}

#[test]
fn widening_as_casts_to_wide_targets_are_not_flagged() {
    let src = "pub fn f(x: u8) -> u64 {\n    x as u64\n}\n";
    expect("src/data/shard/format.rs", src, &[]);
}

// ---------------------------------------------------------------- waivers

#[test]
fn waiver_on_the_preceding_line_absorbs_the_finding_and_is_used() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    // ANALYZE-ALLOW(no-unwrap): fixture reason\n    x.unwrap()\n}\n";
    let fa = analyze_source("src/foo.rs", src);
    assert!(fa.findings.is_empty(), "waiver failed to absorb: {:?}", fa.findings);
    assert_eq!(fa.waivers.len(), 1);
    assert!(fa.waivers[0].used, "absorbing waiver not marked used");
    assert_eq!(fa.waivers[0].rule, "no-unwrap");
}

#[test]
fn trailing_waiver_on_the_same_line_absorbs_the_finding() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    x.unwrap() // ANALYZE-ALLOW(no-unwrap): fixture reason\n}\n";
    let fa = analyze_source("src/foo.rs", src);
    assert!(fa.findings.is_empty());
    assert!(fa.waivers[0].used);
}

#[test]
fn waiver_for_the_wrong_rule_does_not_absorb() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    // ANALYZE-ALLOW(as-truncation): wrong rule\n    x.unwrap()\n}\n";
    let fa = analyze_source("src/foo.rs", src);
    assert_eq!(fa.findings.len(), 1);
    assert_eq!(fa.findings[0].rule.id(), "no-unwrap");
    assert!(!fa.waivers[0].used, "mismatched waiver wrongly marked used");
}

#[test]
fn malformed_waivers_are_findings_with_exact_lines() {
    let src = "// ANALYZE-ALLOW(no-such-rule): bad id\nfn a() {}\n// ANALYZE-ALLOW(no-unwrap) missing colon\nfn b() {}\n// ANALYZE-ALLOW(no-unwrap):\nfn c() {}\n";
    expect(
        "src/foo.rs",
        src,
        &[
            ("waiver-syntax", 1),
            ("waiver-syntax", 3),
            ("waiver-syntax", 5),
        ],
    );
}

#[test]
fn waiver_syntax_itself_cannot_be_waived() {
    let src = "// ANALYZE-ALLOW(waiver-syntax): try to waive the waiver\nfn a() {}\n";
    let fa = analyze_source("src/foo.rs", src);
    assert_eq!(fa.findings.len(), 1);
    assert_eq!(fa.findings[0].rule.id(), "waiver-syntax");
}

// -------------------------------------------------------------- masking

#[test]
fn violations_inside_string_literals_are_invisible() {
    let src = "pub fn f() -> &'static str {\n    \".unwrap() unsafe thread::spawn panic!\"\n}\n";
    expect("src/foo.rs", src, &[]);
}

#[test]
fn violations_inside_comments_are_invisible() {
    let src = "// never call .unwrap() or unsafe thread::spawn here\npub fn f() {}\n";
    expect("src/foo.rs", src, &[]);
}

// ------------------------------------------------------------ self-scan

/// The gate itself: the repo must pass its own lint, every waiver in
/// the tree must be well-formed, and none may be dead. Run the same
/// scan CI runs (`udt analyze`) against this crate's manifest dir.
#[test]
fn repo_self_scan_is_clean_with_no_unused_waivers() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analyze_tree(root).expect("self-scan walks the source tree");
    let rendered = report.render();
    assert_eq!(
        report.total_findings(),
        0,
        "repo fails its own lint:\n{rendered}"
    );
    assert!(
        report.unused_waivers().is_empty(),
        "dead waivers in tree:\n{rendered}"
    );
    // The audit left real, counted waivers behind — the report must
    // show them rather than pretending the tree is waiver-free.
    let waived: usize = report.waiver_counts().iter().map(|(_, n)| n).sum();
    assert!(waived > 0, "expected a nonzero used-waiver count");
    assert!(rendered.contains("0 finding(s)"), "render summary:\n{rendered}");
}
