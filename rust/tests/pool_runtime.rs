//! Witness suite for the persistent worker-pool runtime
//! (`runtime/pool.rs`): training and serving reuse one process-wide set
//! of threads instead of spawning per level / per round / per batch.
//!
//! The pool's counters are process-global and the test harness runs
//! tests concurrently, so assertions here are phrased as process-wide
//! invariants (the spawn total can never exceed `cores() - 1`; after
//! any parallel batch has run, the spawn counter is frozen forever by
//! the `OnceLock`) rather than exact per-test deltas.

use udt::coordinator::parallel::parallel_map;
use udt::coordinator::pipeline::run_pipeline;
use udt::coordinator::registry::ModelRegistry;
use udt::data::synth::{generate_any, SynthSpec};
use udt::inference::RowFrame;
use udt::runtime::{cores, pool_stats};
use udt::tree::forest::ForestConfig;
use udt::tree::tuning::TuneGrid;
use udt::tree::TrainConfig;
use udt::{Boosted, BoostedConfig, Forest, Model, SavedModel};

fn ds(name: &str, rows: usize, seed: u64) -> udt::Dataset {
    let mut spec = SynthSpec::classification(name, rows, 6, 3);
    spec.noise = 0.1;
    generate_any(&spec, seed)
}

/// Force the pool's one-time spawn (on multicore machines) so that a
/// following measured region provably spawns nothing.
fn warm_pool() {
    let xs: Vec<usize> = (0..256).collect();
    let _ = parallel_map(xs, 0, |x| x + 1);
}

fn all_cores_config() -> TrainConfig {
    TrainConfig {
        n_threads: 0,
        ..TrainConfig::default()
    }
}

#[test]
fn forest_fit_spawns_threads_at_most_once() {
    let ds = ds("pool-forest", 2000, 11);
    let cfg = ForestConfig {
        n_trees: 4,
        tree: all_cores_config(),
        ..ForestConfig::default()
    };
    // First fit may trigger the process's single spawn set.
    let first = Forest::fit(&ds, &cfg).unwrap();
    let before = pool_stats();
    // Second full fit: every level of every bagged tree runs on the
    // already-spawned pool.
    let second = Forest::fit(&ds, &cfg).unwrap();
    let delta = pool_stats().delta_since(&before);
    assert_eq!(
        delta.threads_spawned_total, 0,
        "a forest fit spawned threads after the pool was warm"
    );
    assert!(pool_stats().threads_spawned_total <= cores() as u64);
    if cores() > 1 {
        // The fit really did go through the pool.
        assert!(delta.batches_submitted > 0);
        assert!(delta.tasks_executed > 0);
    }
    assert_eq!(first.n_features(), second.n_features());
}

#[test]
fn boost_run_spawns_threads_at_most_once() {
    let ds = ds("pool-boost", 1500, 12);
    let cfg = BoostedConfig {
        n_rounds: 5,
        n_threads: 0,
        ..BoostedConfig::default()
    };
    let _first = Boosted::fit(&ds, &cfg).unwrap();
    let before = pool_stats();
    // 5 more rounds × all their levels: zero spawns.
    let _second = Boosted::fit(&ds, &cfg).unwrap();
    let delta = pool_stats().delta_since(&before);
    assert_eq!(
        delta.threads_spawned_total, 0,
        "a boost run spawned threads after the pool was warm"
    );
    assert!(pool_stats().threads_spawned_total <= cores() as u64);
    if cores() > 1 {
        assert!(delta.batches_submitted > 0);
    }
}

#[test]
fn tuning_sweep_pipeline_reports_pool_counters_and_no_respawn() {
    let ds = ds("pool-pipe", 3000, 13);
    let cfg = all_cores_config();
    let first = run_pipeline(&ds, &cfg, &TuneGrid::default(), 1).unwrap();
    assert!(first.pool_threads_spawned <= cores() as u64);
    // The first run (or any concurrent test) completed a parallel batch,
    // so the OnceLock is set on multicore machines: a second full
    // train → tune → retrain sweep must spawn exactly zero threads.
    let second = run_pipeline(&ds, &cfg, &TuneGrid::default(), 1).unwrap();
    assert_eq!(
        second.pool_threads_spawned, 0,
        "tuning sweep respawned pool threads"
    );
    if cores() > 1 {
        assert!(second.pool_batches > 0, "sweep bypassed the pool");
        assert!(second.pool_tasks > 0);
    }
    // Same data, same seed → identical report modulo timing/counters.
    assert_eq!(first.full_nodes, second.full_nodes);
    assert_eq!(first.best_max_depth, second.best_max_depth);
}

#[test]
fn concurrent_registry_predictions_match_sequential_bit_for_bit() {
    // Two threads driving the registry's compiled predict through the
    // shared pool must see no cross-batch interleaving: every result
    // identical to a sequential run.
    let ds = ds("pool-serve", 1200, 14);
    let tree = udt::Udt::builder().threads(0).fit(&ds).unwrap();
    let registry = ModelRegistry::new();
    registry
        .load("m", SavedModel::new(Model::SingleTree(tree), &ds))
        .unwrap();
    let entry = registry.get(None).unwrap();
    let frame = RowFrame::from_dataset(&ds);

    let expected = entry.predict_frame(&frame).unwrap().into_labels();
    assert_eq!(expected.len(), ds.n_rows());

    std::thread::scope(|scope| {
        for _ in 0..2 {
            scope.spawn(|| {
                for _ in 0..8 {
                    let got = entry.predict_frame(&frame).unwrap().into_labels();
                    assert_eq!(got, expected, "concurrent predict diverged");
                }
            });
        }
    });
    // Serving concurrency never grows the pool past its cap either.
    assert!(pool_stats().threads_spawned_total <= cores() as u64);
}

#[test]
fn panicking_batch_leaves_pool_usable_for_training() {
    warm_pool();
    let poisoned = std::panic::catch_unwind(|| {
        let xs: Vec<usize> = (0..128).collect();
        parallel_map(xs, 0, |x| {
            if x == 77 {
                panic!("task failure");
            }
            x
        })
    });
    assert!(poisoned.is_err(), "panic must propagate to the submitter");
    // A real training run straight after works on the same pool.
    let ds = ds("pool-panic", 1000, 15);
    let tree = udt::Udt::builder().threads(0).fit(&ds).unwrap();
    assert!(tree.n_nodes() >= 3);
    let before = pool_stats();
    let tree2 = udt::Udt::builder().threads(0).fit(&ds).unwrap();
    assert_eq!(tree.n_nodes(), tree2.n_nodes());
    assert_eq!(
        pool_stats().delta_since(&before).threads_spawned_total,
        0,
        "recovery must not respawn workers"
    );
}

#[test]
fn zero_threads_trains_identically_to_explicit_core_count() {
    // The n_threads == 0 semantics regression, end to end: 0 ("all
    // cores"), 1 (sequential) and an explicit count all build the same
    // tree thanks to order-preserving, thread-count-invariant batches.
    let ds = ds("pool-zero", 1800, 16);
    let fit = |threads: usize| udt::Udt::builder().threads(threads).fit(&ds).unwrap();
    let seq = fit(1);
    let zero = fit(0);
    let four = fit(4);
    assert_eq!(seq.n_nodes(), zero.n_nodes());
    assert_eq!(seq.n_nodes(), four.n_nodes());
    assert_eq!(seq.depth, zero.depth);
    for r in 0..ds.n_rows() {
        let a = udt::tree::predict::predict_ds(&seq, &ds, r, usize::MAX, 0);
        assert_eq!(a, udt::tree::predict::predict_ds(&zero, &ds, r, usize::MAX, 0));
        assert_eq!(a, udt::tree::predict::predict_ds(&four, &ds, r, usize::MAX, 0));
    }
}
