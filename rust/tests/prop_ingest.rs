//! Property suite for streaming CSV ingest: the chunk-parallel typed
//! parser must produce a dataset **bit-identical** to the legacy
//! row-materializing reference path — values (including interner ids),
//! labels, class-name order, and interner-resolved strings — on random
//! hybrid CSVs with quotes, CRLF line endings, missing cells, and for
//! 1 ≡ N parse threads at any chunk size.

use udt::data::csv::{load_csv_str, load_csv_str_rowwise, CsvOptions};
use udt::data::dataset::{Dataset, Labels, TaskKind};
use udt::data::value::Value;
use udt::util::prop::{check, Config};
use udt::util::rng::Rng;

/// Random cell text: numeric, categorical (sometimes needing quotes),
/// or missing. Returns the field as it should appear in the CSV.
fn random_field(rng: &mut Rng) -> String {
    match rng.below(10) {
        0 => String::new(),                       // missing: empty
        1 => "?".to_string(),                     // missing: sentinel
        2 | 3 => format!("s{}", rng.below(6)),    // plain categorical
        4 => {
            // Categorical requiring quoting (embedded comma / quote).
            match rng.below(3) {
                0 => format!("\"a,{}\"", rng.below(4)),
                1 => "\"say \"\"hi\"\"\"".to_string(),
                _ => format!("\"{} sp\"", rng.below(4)),
            }
        }
        5 => format!("{}", rng.below(50) as f64 / 4.0), // small float grid
        _ => format!("{}", rng.below(100)),             // integer
    }
}

/// Generate random hybrid CSV text plus the options to parse it.
fn random_csv(rng: &mut Rng, size: usize) -> (String, CsvOptions) {
    let n_rows = rng.range(1, size.max(2));
    let n_cols = rng.range(2, 6);
    let regression = rng.chance(0.3);
    let has_header = rng.chance(0.7);
    let crlf = rng.chance(0.4);
    let eol = if crlf { "\r\n" } else { "\n" };

    let mut text = String::new();
    if has_header {
        for c in 0..n_cols {
            if c > 0 {
                text.push(',');
            }
            text.push_str(&format!("col{c}"));
        }
        text.push_str(eol);
    }
    for _ in 0..n_rows {
        for c in 0..n_cols {
            if c > 0 {
                text.push(',');
            }
            if c == n_cols - 1 {
                // Label column.
                if regression {
                    text.push_str(&format!("{}", rng.below(1000) as f64 / 8.0));
                } else {
                    text.push_str(&format!("cls{}", rng.below(4)));
                }
            } else {
                text.push_str(&random_field(rng));
            }
        }
        text.push_str(eol);
        if rng.chance(0.1) {
            text.push_str(eol); // interspersed blank line
        }
    }

    let opts = CsvOptions {
        has_header,
        task: if regression {
            TaskKind::Regression
        } else {
            TaskKind::Classification
        },
        ..Default::default()
    };
    (text, opts)
}

/// Bit-identity check: shapes, names, per-cell values *including*
/// categorical ids, interner-resolved strings, labels and class-name
/// order.
fn datasets_identical(a: &Dataset, b: &Dataset) -> Result<(), String> {
    if a.n_rows() != b.n_rows() || a.n_features() != b.n_features() {
        return Err(format!(
            "shape mismatch: {}x{} vs {}x{}",
            a.n_rows(),
            a.n_features(),
            b.n_rows(),
            b.n_features()
        ));
    }
    if a.interner.names() != b.interner.names() {
        return Err(format!(
            "interner order diverged: {:?} vs {:?}",
            a.interner.names(),
            b.interner.names()
        ));
    }
    if *a.class_names != *b.class_names {
        return Err(format!(
            "class-name order diverged: {:?} vs {:?}",
            a.class_names, b.class_names
        ));
    }
    for f in 0..a.n_features() {
        if a.columns[f].name != b.columns[f].name {
            return Err(format!(
                "feature {f} name: {} vs {}",
                a.columns[f].name, b.columns[f].name
            ));
        }
        for r in 0..a.n_rows() {
            let (va, vb) = (a.value(f, r), b.value(f, r));
            let same = match (va, vb) {
                (Value::Num(x), Value::Num(y)) => x == y,
                // Ids must match exactly, not just resolve to the same
                // string — downstream model bundles bake the id order.
                (Value::Cat(x), Value::Cat(y)) => {
                    x == y && a.interner.name(x) == b.interner.name(y)
                }
                (Value::Missing, Value::Missing) => true,
                _ => false,
            };
            if !same {
                return Err(format!("cell ({f},{r}): {va:?} vs {vb:?}"));
            }
        }
    }
    match (&a.labels, &b.labels) {
        (
            Labels::Class { ids: x, n_classes: nx },
            Labels::Class { ids: y, n_classes: ny },
        ) => {
            if x != y || nx != ny {
                return Err("class labels diverged".into());
            }
        }
        (Labels::Reg { values: x }, Labels::Reg { values: y }) => {
            if x != y {
                return Err("regression labels diverged".into());
            }
        }
        _ => return Err("label kind diverged".into()),
    }
    Ok(())
}

#[test]
fn streaming_ingest_is_bit_identical_to_rowwise_reference() {
    check(
        "streaming csv ≡ rowwise reference",
        Config::default().cases(60).max_size(120).seed(0x1_C5F_2024),
        |rng, size| {
            let (text, base) = random_csv(rng, size);
            let reference = load_csv_str_rowwise("ref", &text, &base)
                .map_err(|e| format!("reference parse failed: {e}\n{text}"))?;
            for (threads, chunk) in [(1, 0), (1, 13), (4, 0), (4, 7), (7, 1)] {
                let opts = CsvOptions {
                    n_threads: threads,
                    chunk_bytes: chunk,
                    ..base.clone()
                };
                let streamed = load_csv_str("ref", &text, &opts)
                    .map_err(|e| format!("streaming parse failed (t={threads} c={chunk}): {e}\n{text}"))?;
                datasets_identical(&reference, &streamed).map_err(|m| {
                    format!("t={threads} chunk={chunk}: {m}\ncsv:\n{text}")
                })?;
            }
            Ok(())
        },
    );
}

#[test]
fn streaming_ingest_rejects_what_the_reference_rejects() {
    check(
        "streaming csv error parity",
        Config::default().cases(30).max_size(60).seed(0xBAD_C5F),
        |rng, size| {
            let (mut text, base) = random_csv(rng, size);
            // Corrupt the input: append a ragged row.
            text.push_str("only-one-field\n");
            let r = load_csv_str_rowwise("bad", &text, &base);
            for threads in [1, 5] {
                let s = load_csv_str(
                    "bad",
                    &text,
                    &CsvOptions {
                        n_threads: threads,
                        chunk_bytes: 11,
                        ..base.clone()
                    },
                );
                if r.is_err() != s.is_err() {
                    return Err(format!(
                        "error parity broke (t={threads}): rowwise {:?} vs streaming {:?}\n{text}",
                        r.as_ref().err().map(|e| e.to_string()),
                        s.err().map(|e| e.to_string()),
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn frame_csv_and_dataset_csv_classify_cells_identically() {
    // The serving CSV path routes through the same streaming parser; a
    // feature-only parse of the feature columns must classify every cell
    // exactly like dataset ingest does.
    let text = "a,b,label\n1.5,red,x\n?,\"b,lue\",y\n2,,x\ncat,3,y\n";
    let ds = load_csv_str("t", text, &CsvOptions::default()).unwrap();
    // Drop the label column to build the serving-side input.
    let feature_text = "a,b\n1.5,red\n?,\"b,lue\"\n2,\ncat,3\n";
    let frame = udt::inference::RowFrame::from_csv_str(feature_text, true, ',').unwrap();
    assert_eq!(frame.n_rows(), ds.n_rows());
    assert_eq!(frame.n_features(), ds.n_features());
    for f in 0..ds.n_features() {
        for r in 0..ds.n_rows() {
            match (ds.value(f, r), frame.cell(f, r)) {
                (Value::Num(a), Value::Num(b)) => assert_eq!(a, b),
                (Value::Cat(a), Value::Cat(b)) => {
                    assert_eq!(ds.interner.name(a), frame.interner().name(b))
                }
                (Value::Missing, Value::Missing) => {}
                (a, b) => panic!("cell ({f},{r}): {a:?} vs {b:?}"),
            }
        }
    }
}
